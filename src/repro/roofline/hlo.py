"""Collective-traffic extraction from optimized HLO text + 3-term roofline.

cost_analysis() gives HLO FLOPs and bytes but NOT collective traffic; we
parse the compiled module text and account every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute.

Accounting (per device, ring algorithm):
  all-reduce       2 * size * (G-1)/G      (reduce-scatter + all-gather)
  all-gather       out_size * (G-1)/G
  reduce-scatter   in_size  * (G-1)/G
  all-to-all       size * (G-1)/G
  collective-permute  size
plus the raw operand-size sum (the assignment's simpler metric) — both are
reported; the time term uses the ring wire bytes.

Hardware constants (TPU v5e, per assignment): 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s+(\([^=]*?\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclasses.dataclass
class CollectiveStats:
    ops: List[Dict]
    operand_bytes: int           # assignment metric: sum of operand sizes
    wire_bytes: int              # ring-model bytes per device

    def by_kind(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for op in self.ops:
            out[op["kind"]] = out.get(op["kind"], 0) + op["wire_bytes"]
        return out


def parse_collectives(hlo_text: str) -> CollectiveStats:
    ops = []
    operand_total = 0
    wire_total = 0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        out_shape, kind = m.group(1), m.group(2)
        out_bytes = _shape_bytes(out_shape)
        g = max(_group_size(line), 1)
        if kind == "all-reduce":
            operand = out_bytes
            wire = int(2 * out_bytes * (g - 1) / g)
        elif kind == "all-gather":
            operand = out_bytes // g
            wire = int(out_bytes * (g - 1) / g)
        elif kind == "reduce-scatter":
            operand = out_bytes * g
            wire = int(operand * (g - 1) / g)
        elif kind == "all-to-all":
            operand = out_bytes
            wire = int(out_bytes * (g - 1) / g)
        else:  # collective-permute
            operand = out_bytes
            wire = out_bytes
        ops.append({"kind": kind, "bytes": out_bytes, "group": g,
                    "operand_bytes": operand, "wire_bytes": wire})
        operand_total += operand
        wire_total += wire
    return CollectiveStats(ops, operand_total, wire_total)


def roofline_terms(flops_per_device: float, hbm_bytes_per_device: float,
                   wire_bytes_per_device: float) -> Dict[str, float]:
    """Three per-device time terms (seconds) + the dominant bottleneck."""
    t_compute = flops_per_device / PEAK_FLOPS
    t_memory = hbm_bytes_per_device / HBM_BW
    t_collective = wire_bytes_per_device / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    # Roofline fraction: useful-compute time over the max term (how close the
    # dominant resource is to being the only cost).
    tmax = max(t_compute, t_memory, t_collective)
    terms["compute_fraction_of_bound"] = t_compute / tmax if tmax > 0 else 0.0
    return terms
