"""Flight recorder: bounded in-memory event ring + incident dumps.

Always-on trace files are too expensive for a long-running service, but
when a request dies (error/deadline) or the circuit breaker trips you
want the recent past, not just counters.  :class:`FlightRecorder` keeps
a fixed-capacity ring of recent events — span summaries, status
transitions, breaker state changes, admission decisions — each stamped
with wall/monotonic time and any active trace context, and
:meth:`incident` snapshots the last ``window_s`` seconds of that ring
into a self-contained JSON file.

Bounds (DESIGN.md §16): memory is capped by ``capacity`` (a deque
maxlen — old events fall off silently), disk by ``max_incidents`` per
recorder (later triggers increment a dropped counter instead of
writing), and each dump covers at most the ring ∩ window, so a trigger
storm cannot fill the disk or stall the serving path: ``note`` is one
lock + deque append.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

from repro.obs.context import current_context
from repro.obs.telemetry import jsonable

__all__ = ["FlightRecorder", "load_incident"]


class FlightRecorder:
    def __init__(self, dir: Optional[str] = None, capacity: int = 4096,
                 window_s: float = 30.0, max_incidents: int = 50,
                 process_name: str = "main",
                 enabled: Optional[bool] = None):
        self.dir = dir
        self.enabled = bool(dir) if enabled is None else bool(enabled)
        self.capacity = int(capacity)
        self.window_s = float(window_s)
        self.max_incidents = int(max_incidents)
        self.process_name = process_name
        self._lock = threading.Lock()
        self._ring: List[dict] = []          # bounded manually (ring index)
        self._head = 0
        self._seq = 0
        self._incidents: List[str] = []
        self._dropped_incidents = 0
        if self.enabled and dir is not None:
            os.makedirs(dir, exist_ok=True)

    # -- recording -----------------------------------------------------------
    def note(self, kind: str, **fields):
        """Append one event to the ring. Cheap; safe from any thread."""
        if not self.enabled:
            return
        ev: Dict[str, Any] = {"kind": kind, "t_wall": time.time(),
                              "t_mono": time.monotonic()}
        ctx = current_context()
        if ctx is not None:
            ev["trace_id"] = ctx.trace_id
            ev["span_id"] = ctx.span_id
        if fields:
            ev.update(fields)
        with self._lock:
            self._seq += 1
            ev["seq"] = self._seq
            if len(self._ring) < self.capacity:
                self._ring.append(ev)
            else:
                self._ring[self._head] = ev
                self._head = (self._head + 1) % self.capacity

    def _recent(self, window_s: float) -> List[dict]:
        # caller holds the lock; returns events in seq order
        lo = time.monotonic() - window_s
        ordered = self._ring[self._head:] + self._ring[:self._head]
        return [e for e in ordered if e["t_mono"] >= lo]

    # -- incident dumps ------------------------------------------------------
    def incident(self, reason: str, **fields) -> Optional[str]:
        """Dump the recent ring to ``incident-NNN-<reason>.json``.

        Returns the path, or None when disabled / over the incident cap.
        """
        if not self.enabled or self.dir is None:
            return None
        with self._lock:
            if len(self._incidents) >= self.max_incidents:
                self._dropped_incidents += 1
                return None
            events = self._recent(self.window_s)
            n = len(self._incidents)
        safe = "".join(c if c.isalnum() or c in "-_" else "_"
                       for c in reason)[:60]
        path = os.path.join(self.dir, f"incident-{n:03d}-{safe}.json")
        doc = {
            "reason": reason,
            "t_wall": time.time(),
            "window_s": self.window_s,
            "process": {"pid": os.getpid(), "name": self.process_name},
            "trigger": jsonable(fields),
            "events": jsonable(events),
        }
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        os.replace(tmp, path)      # a torn dump never shadows a good one
        with self._lock:
            self._incidents.append(path)
        return path

    # -- introspection -------------------------------------------------------
    def incidents(self) -> List[str]:
        with self._lock:
            return list(self._incidents)

    def snapshot(self) -> dict:
        with self._lock:
            return {"events_recorded": self._seq,
                    "ring_size": len(self._ring),
                    "capacity": self.capacity,
                    "incidents": len(self._incidents),
                    "incidents_dropped": self._dropped_incidents}


def load_incident(path: str) -> dict:
    """Load one incident dump (they are written atomically, so plain
    json.load; raises on a file that isn't an incident dump)."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "reason" not in doc:
        raise ValueError(f"not a flight-recorder incident file: {path}")
    doc.setdefault("events", [])
    return doc


NOOP = FlightRecorder(dir=None, enabled=False)
