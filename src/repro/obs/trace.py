"""Low-overhead tracing spans -> Chrome-trace/Perfetto JSON (DESIGN.md §12).

``Tracer.span("gram_pass", attrs=...)`` is a context manager that records
one complete ("ph": "X") event; ``@tracer.traced()`` wraps a function.
Events carry real OS pid/tid plus ``process_name`` / ``thread_name``
metadata, so a multi-process cluster solve — coordinator + N workers,
each exporting its own event list — merges into ONE timeline: load the
exported JSON in ``chrome://tracing`` or https://ui.perfetto.dev and
every process renders as its own track.

Clock contract: event timestamps (``ts``) are wall-clock microseconds
(``time.time_ns``), the one clock processes on a host share, so merged
cross-process events align; durations (``dur``) come from
``time.perf_counter`` for sub-microsecond resolution within a span.

Disabled fast path: ``span`` on a disabled tracer returns a reused no-op
context manager — no event dict, no timestamp read, no allocation — so
instrumented code costs one attribute check when observability is off.

Trace-context integration (DESIGN.md §16): when a request-scoped
:class:`~repro.obs.context.TraceContext` is active (contextvar), each
span pushes a *child* context for its dynamic extent and stamps
``trace_id``/``span_id``/``parent_id`` into its event args.  Nested
spans therefore form a parent chain, and anything sent over the
transport from inside a span carries that span's context — which is how
a client span becomes the ancestor of a frontend/executor span in
another process.  ``complete_at`` records a span retroactively from
stored timestamps (queue wait: nobody is "in" the span while a request
sits in the queue).
"""
from __future__ import annotations

import functools
import json
import os
import threading
import time
from typing import List, Optional

from repro.obs.context import TraceContext, current_context, use_context


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "_name", "_attrs", "_t0_us", "_t0", "_ctx",
                 "_cm")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs

    def __enter__(self):
        parent = current_context()
        if parent is not None:
            # This span is a new node in the request's trace: push a child
            # context so nested spans (and frames sent from inside) chain
            # under it.
            self._ctx = parent.child()
            self._cm = use_context(self._ctx)
            self._cm.__enter__()
        else:
            self._ctx = None
            self._cm = None
        self._t0_us = time.time_ns() // 1000
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur_us = (time.perf_counter() - self._t0) * 1e6
        if self._cm is not None:
            self._cm.__exit__(None, None, None)
        attrs = self._attrs
        if self._ctx is not None:
            attrs = dict(attrs)
            attrs["trace_id"] = self._ctx.trace_id
            attrs["span_id"] = self._ctx.span_id
            if self._ctx.parent_id is not None:
                attrs["parent_id"] = self._ctx.parent_id
        self._tracer._emit(self._name, self._t0_us, dur_us, attrs)
        return False

    @property
    def context(self) -> Optional[TraceContext]:
        """The child TraceContext this span pushed (None untraced)."""
        return self._ctx


class Tracer:
    def __init__(self, enabled: bool = False,
                 process_name: Optional[str] = None):
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._events: List[dict] = []
        self._pid = os.getpid()
        self._named_tids: set = set()
        if process_name and self.enabled:
            self.set_process_name(process_name)

    # -- recording -----------------------------------------------------------
    def set_process_name(self, name: str, pid: Optional[int] = None):
        with self._lock:
            self._events.append({"ph": "M", "name": "process_name",
                                 "pid": pid if pid is not None else self._pid,
                                 "tid": 0, "args": {"name": name}})

    def span(self, name: str, **attrs):
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, attrs)

    def traced(self, name: Optional[str] = None):
        def deco(fn):
            label = name or fn.__qualname__

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                if not self.enabled:
                    return fn(*args, **kwargs)
                with self.span(label):
                    return fn(*args, **kwargs)

            return wrapper

        return deco

    def complete_at(self, name: str, t0_us: int, dur_s: float,
                    ctx: Optional[TraceContext] = None, **attrs):
        """Record a complete span retroactively from stored timestamps.

        Used for intervals with no live frame on any stack — queue wait
        is the canonical one: the request sat in the pending map between
        ``t0_us`` (wall-clock µs at enqueue) and now.  ``ctx`` parents
        the emitted span under a request's trace; a fresh span id is
        minted so sibling retro-spans don't collide.
        """
        if not self.enabled:
            return
        if ctx is not None:
            node = ctx.child()
            attrs = dict(attrs)
            attrs["trace_id"] = node.trace_id
            attrs["span_id"] = node.span_id
            if node.parent_id is not None:
                attrs["parent_id"] = node.parent_id
        self._emit(name, int(t0_us), max(dur_s, 0.0) * 1e6, attrs)

    def instant(self, name: str, **attrs):
        if not self.enabled:
            return
        tid = threading.get_ident()
        ev = {"ph": "i", "name": name, "ts": time.time_ns() // 1000,
              "pid": self._pid, "tid": tid, "s": "p"}
        if attrs:
            ev["args"] = attrs
        with self._lock:
            self._maybe_name_thread(tid)
            self._events.append(ev)

    def _emit(self, name: str, t0_us: int, dur_us: float, attrs: dict):
        tid = threading.get_ident()
        ev = {"ph": "X", "name": name, "ts": t0_us,
              "dur": round(dur_us, 3), "pid": self._pid, "tid": tid}
        if attrs:
            ev["args"] = attrs
        with self._lock:
            self._maybe_name_thread(tid)
            self._events.append(ev)

    def _maybe_name_thread(self, tid: int):
        # caller holds the lock
        if tid in self._named_tids:
            return
        self._named_tids.add(tid)
        self._events.append({"ph": "M", "name": "thread_name",
                             "pid": self._pid, "tid": tid,
                             "args": {"name": threading.current_thread().name}})

    # -- merge / export ------------------------------------------------------
    def add_events(self, events: List[dict],
                   process_name: Optional[str] = None,
                   pid: Optional[int] = None):
        """Fold another process's event list in (cluster workers ship
        theirs to the coordinator at shutdown). ``process_name``/``pid``
        add the process metadata track when the shipped list lacks it."""
        with self._lock:
            if process_name is not None and pid is not None:
                if not any(e.get("ph") == "M"
                           and e.get("name") == "process_name"
                           and e.get("pid") == pid for e in events):
                    self._events.append(
                        {"ph": "M", "name": "process_name", "pid": pid,
                         "tid": 0, "args": {"name": process_name}})
            self._events.extend(events)

    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def export(self, path: str):
        with open(path, "w") as f:
            json.dump({"traceEvents": self.events(),
                       "displayTimeUnit": "ms"}, f)
            f.write("\n")


def load_trace(path: str) -> List[dict]:
    """Load a trace.json; tolerates a truncated file (killed writer).

    A SIGKILL mid-``export`` leaves a prefix of the JSON document on
    disk.  Rather than fail, salvage every complete event object from
    the ``traceEvents`` array — the crash-safe-artifacts contract
    (DESIGN.md §16): partially-written artifacts still load.
    """
    with open(path) as f:
        text = f.read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        return _recover_truncated_trace(text)
    return doc["traceEvents"] if isinstance(doc, dict) else doc


def _recover_truncated_trace(text: str) -> List[dict]:
    start = text.find("[")
    if start < 0:
        return []
    dec = json.JSONDecoder()
    events: List[dict] = []
    pos = start + 1
    n = len(text)
    while pos < n:
        while pos < n and text[pos] in ", \t\r\n":
            pos += 1
        if pos >= n or text[pos] == "]":
            break
        try:
            ev, pos = dec.raw_decode(text, pos)
        except json.JSONDecodeError:
            break  # truncated mid-object: keep what we have
        if isinstance(ev, dict):
            events.append(ev)
    return events


def span_tree(events: List[dict]) -> dict:
    """Index context-stamped spans: {span_id: event} for one trace set.

    Helper for connectivity checks ("is the client span an ancestor of
    the executor span?") over merged multi-process events.
    """
    by_id: dict = {}
    for e in events:
        if e.get("ph") != "X":
            continue
        args = e.get("args") or {}
        sid = args.get("span_id")
        if isinstance(sid, str):
            by_id[sid] = e
    return by_id


def is_ancestor(events: List[dict], ancestor_span_id: str,
                span_id: str) -> bool:
    """True if ``ancestor_span_id`` is on ``span_id``'s parent chain
    (walked through the stamped args of context-carrying spans)."""
    by_id = span_tree(events)
    seen = set()
    cur = by_id.get(span_id)
    while cur is not None:
        pid = (cur.get("args") or {}).get("parent_id")
        if pid == ancestor_span_id:
            return True
        if not isinstance(pid, str) or pid in seen:
            return False
        seen.add(pid)
        cur = by_id.get(pid)
    return False


def span_hotspots(events: List[dict]) -> List[dict]:
    """Aggregate complete ("X") events by name: count, total/mean ms —
    the obs_report 'where did the time go' table."""
    agg: dict = {}
    for e in events:
        if e.get("ph") != "X":
            continue
        a = agg.setdefault(e["name"], {"name": e["name"], "count": 0,
                                       "total_ms": 0.0})
        a["count"] += 1
        a["total_ms"] += e.get("dur", 0.0) / 1e3
    out = sorted(agg.values(), key=lambda a: -a["total_ms"])
    for a in out:
        a["total_ms"] = round(a["total_ms"], 3)
        a["mean_ms"] = round(a["total_ms"] / max(a["count"], 1), 3)
    return out
