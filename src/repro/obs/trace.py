"""Low-overhead tracing spans -> Chrome-trace/Perfetto JSON (DESIGN.md §12).

``Tracer.span("gram_pass", attrs=...)`` is a context manager that records
one complete ("ph": "X") event; ``@tracer.traced()`` wraps a function.
Events carry real OS pid/tid plus ``process_name`` / ``thread_name``
metadata, so a multi-process cluster solve — coordinator + N workers,
each exporting its own event list — merges into ONE timeline: load the
exported JSON in ``chrome://tracing`` or https://ui.perfetto.dev and
every process renders as its own track.

Clock contract: event timestamps (``ts``) are wall-clock microseconds
(``time.time_ns``), the one clock processes on a host share, so merged
cross-process events align; durations (``dur``) come from
``time.perf_counter`` for sub-microsecond resolution within a span.

Disabled fast path: ``span`` on a disabled tracer returns a reused no-op
context manager — no event dict, no timestamp read, no allocation — so
instrumented code costs one attribute check when observability is off.
"""
from __future__ import annotations

import functools
import json
import os
import threading
import time
from typing import List, Optional


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "_name", "_attrs", "_t0_us", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs

    def __enter__(self):
        self._t0_us = time.time_ns() // 1000
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur_us = (time.perf_counter() - self._t0) * 1e6
        self._tracer._emit(self._name, self._t0_us, dur_us, self._attrs)
        return False


class Tracer:
    def __init__(self, enabled: bool = False,
                 process_name: Optional[str] = None):
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._events: List[dict] = []
        self._pid = os.getpid()
        self._named_tids: set = set()
        if process_name and self.enabled:
            self.set_process_name(process_name)

    # -- recording -----------------------------------------------------------
    def set_process_name(self, name: str, pid: Optional[int] = None):
        with self._lock:
            self._events.append({"ph": "M", "name": "process_name",
                                 "pid": pid if pid is not None else self._pid,
                                 "tid": 0, "args": {"name": name}})

    def span(self, name: str, **attrs):
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, attrs)

    def traced(self, name: Optional[str] = None):
        def deco(fn):
            label = name or fn.__qualname__

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                if not self.enabled:
                    return fn(*args, **kwargs)
                with self.span(label):
                    return fn(*args, **kwargs)

            return wrapper

        return deco

    def instant(self, name: str, **attrs):
        if not self.enabled:
            return
        tid = threading.get_ident()
        ev = {"ph": "i", "name": name, "ts": time.time_ns() // 1000,
              "pid": self._pid, "tid": tid, "s": "p"}
        if attrs:
            ev["args"] = attrs
        with self._lock:
            self._maybe_name_thread(tid)
            self._events.append(ev)

    def _emit(self, name: str, t0_us: int, dur_us: float, attrs: dict):
        tid = threading.get_ident()
        ev = {"ph": "X", "name": name, "ts": t0_us,
              "dur": round(dur_us, 3), "pid": self._pid, "tid": tid}
        if attrs:
            ev["args"] = attrs
        with self._lock:
            self._maybe_name_thread(tid)
            self._events.append(ev)

    def _maybe_name_thread(self, tid: int):
        # caller holds the lock
        if tid in self._named_tids:
            return
        self._named_tids.add(tid)
        self._events.append({"ph": "M", "name": "thread_name",
                             "pid": self._pid, "tid": tid,
                             "args": {"name": threading.current_thread().name}})

    # -- merge / export ------------------------------------------------------
    def add_events(self, events: List[dict],
                   process_name: Optional[str] = None,
                   pid: Optional[int] = None):
        """Fold another process's event list in (cluster workers ship
        theirs to the coordinator at shutdown). ``process_name``/``pid``
        add the process metadata track when the shipped list lacks it."""
        with self._lock:
            if process_name is not None and pid is not None:
                if not any(e.get("ph") == "M"
                           and e.get("name") == "process_name"
                           and e.get("pid") == pid for e in events):
                    self._events.append(
                        {"ph": "M", "name": "process_name", "pid": pid,
                         "tid": 0, "args": {"name": process_name}})
            self._events.extend(events)

    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def export(self, path: str):
        with open(path, "w") as f:
            json.dump({"traceEvents": self.events(),
                       "displayTimeUnit": "ms"}, f)
            f.write("\n")


def load_trace(path: str) -> List[dict]:
    with open(path) as f:
        doc = json.load(f)
    return doc["traceEvents"] if isinstance(doc, dict) else doc


def span_hotspots(events: List[dict]) -> List[dict]:
    """Aggregate complete ("X") events by name: count, total/mean ms —
    the obs_report 'where did the time go' table."""
    agg: dict = {}
    for e in events:
        if e.get("ph") != "X":
            continue
        a = agg.setdefault(e["name"], {"name": e["name"], "count": 0,
                                       "total_ms": 0.0})
        a["count"] += 1
        a["total_ms"] += e.get("dur", 0.0) / 1e3
    out = sorted(agg.values(), key=lambda a: -a["total_ms"])
    for a in out:
        a["total_ms"] = round(a["total_ms"], 3)
        a["mean_ms"] = round(a["total_ms"] / max(a["count"], 1), 3)
    return out
