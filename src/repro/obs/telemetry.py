"""Structured per-iteration telemetry: a JSONL sink (DESIGN.md §12).

One line per solver iteration — iter index, primal/dual residuals,
objective, tau/rho, block timings, bytes by message type — written by
the HOST loop of whichever topology is solving (streaming sweep loop,
cluster coordinator, post-scan history dump for the fully-jitted
drivers). JSONL because the stream is append-only (a killed run keeps
every completed line) and line-parseable without loading the file.

Values are sanitized to plain JSON: numpy/jax scalars unwrap, arrays
become lists, NaN/inf become null (bare NaN is invalid JSON — the
BENCH_*.json convention).
"""
from __future__ import annotations

import json
import math
import threading
from typing import Any, List, Optional


def jsonable(v: Any):
    if v is None or isinstance(v, (str, bool, int)):
        return v
    if isinstance(v, float):
        return v if math.isfinite(v) else None
    if isinstance(v, dict):
        return {str(k): jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [jsonable(x) for x in v]
    # numpy / jax scalars and arrays
    item = getattr(v, "item", None)
    if item is not None and getattr(v, "ndim", None) == 0:
        return jsonable(item())
    tolist = getattr(v, "tolist", None)
    if tolist is not None:
        return jsonable(tolist())
    return str(v)


class TelemetryWriter:
    """Append-only JSONL sink; ``write`` is thread-safe and flushes per
    line so a SIGKILL keeps every completed record."""

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        self._f: Optional[Any] = open(path, "w")

    def write(self, record: dict):
        line = json.dumps(jsonable(record))
        with self._lock:
            if self._f is None:
                return
            self._f.write(line + "\n")
            self._f.flush()

    def close(self):
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None


def read_jsonl(path: str) -> List[dict]:
    """Read a JSONL file, truncating at the first undecodable line.

    A process killed mid-``write`` leaves at most one partial trailing
    line; stopping at the first bad line keeps every complete record and
    never raises for a torn tail (DESIGN.md §16 crash-safe artifacts).
    """
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                break
    return out
