"""Process-local, thread-safe metrics registry (DESIGN.md §12).

One registry = one process's counters, gauges, and histograms, each
addressed by ``(name, labels)``. The registry exists to replace the
repo's scattered one-off accounting (``service.server.ServerCounters``'
racy ``+=`` fields, ``cluster.transport.ByteCounter``'s hand-rolled
dicts) with ONE mergeable schema:

  * ``snapshot()`` produces a plain-JSON dict that crosses process
    boundaries (cluster workers ship theirs in heartbeats and at
    shutdown);
  * ``merge(snapshot)`` folds another process's snapshot in — counters
    and histogram bucket counts ADD, gauges take the incoming value,
    min/max combine — optionally relabelled (``extra_labels``) so a
    coordinator can keep per-worker series side by side;
  * histograms use FIXED log-spaced buckets (32 per decade over
    [1e-7, 1e5)), so merged percentile estimates are exact merges of the
    underlying distributions: quantile error is bounded by the bucket
    width (a factor of 10^(1/32) ≈ 7.5%, ≈ 3.7% at the geometric
    midpoint) regardless of how many snapshots were folded.

Everything here is pure stdlib and allocation-light: an ``inc`` or
``observe`` is one lock acquire + dict update, cheap enough for
per-block hot paths on the HOST side (never called from jitted code —
DESIGN.md §12's overhead budget).
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Iterable, Optional, Tuple

# -- fixed log-spaced histogram geometry -------------------------------------
HIST_LO = 1e-7                  # 100 ns — below any timeable latency
HIST_DECADES = 12               # up to 1e5 (> a day, in seconds)
BUCKETS_PER_DECADE = 32
NBUCKETS = HIST_DECADES * BUCKETS_PER_DECADE
# counts index 0 = underflow (v < HIST_LO), 1..NBUCKETS = log buckets,
# NBUCKETS + 1 = overflow.

_LabelKey = Tuple[Tuple[str, str], ...]


def _key(name: str, labels: Dict[str, str]) -> Tuple[str, _LabelKey]:
    return (name, tuple(sorted((str(k), str(v))
                               for k, v in labels.items())))


def _bucket_index(v: float) -> int:
    if not v > 0 or v < HIST_LO:
        return 0
    i = 1 + int(math.log10(v / HIST_LO) * BUCKETS_PER_DECADE)
    return min(i, NBUCKETS + 1)


def _bucket_mid(i: int) -> float:
    """Geometric midpoint of bucket i (1-based log buckets)."""
    lo = HIST_LO * 10.0 ** ((i - 1) / BUCKETS_PER_DECADE)
    return lo * 10.0 ** (0.5 / BUCKETS_PER_DECADE)


class Histogram:
    """Sparse fixed-bucket histogram. NOT thread-safe on its own — the
    registry serializes access; standalone use is single-threaded
    (snapshot decoding in reports)."""

    __slots__ = ("counts", "sum", "count", "min", "max")

    def __init__(self):
        self.counts: Dict[int, int] = {}
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float):
        v = float(v)
        i = _bucket_index(v)
        self.counts[i] = self.counts.get(i, 0) + 1
        self.sum += v
        self.count += 1
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def quantile(self, q: float) -> Optional[float]:
        """Estimate the q-quantile (q in [0, 1]) from the buckets; the
        estimate is clamped to the observed [min, max]."""
        if self.count == 0:
            return None
        target = max(1.0, math.ceil(q * self.count))
        cum = 0
        for i in sorted(self.counts):
            cum += self.counts[i]
            if cum >= target:
                if i == 0:
                    est = HIST_LO
                elif i == NBUCKETS + 1:
                    est = self.max
                else:
                    est = _bucket_mid(i)
                return min(max(est, self.min), self.max)
        return self.max

    @property
    def mean(self) -> Optional[float]:
        return self.sum / self.count if self.count else None

    def merge(self, other: "Histogram"):
        for i, c in other.counts.items():
            self.counts[i] = self.counts.get(i, 0) + c
        self.sum += other.sum
        self.count += other.count
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    def to_snapshot(self) -> dict:
        return {"counts": {str(i): c for i, c in self.counts.items()},
                "sum": self.sum, "count": self.count,
                "min": (None if self.count == 0 else self.min),
                "max": (None if self.count == 0 else self.max)}

    @classmethod
    def from_snapshot(cls, d: dict) -> "Histogram":
        h = cls()
        h.counts = {int(i): int(c) for i, c in d.get("counts", {}).items()}
        h.sum = float(d.get("sum", 0.0))
        h.count = int(d.get("count", 0))
        h.min = math.inf if d.get("min") is None else float(d["min"])
        h.max = -math.inf if d.get("max") is None else float(d["max"])
        return h


def summarize_histogram(snap: dict, scale: float = 1.0) -> dict:
    """p50/p90/p99 + mean/count from one histogram snapshot (values
    multiplied by ``scale``, e.g. 1e3 for seconds -> ms)."""
    h = Histogram.from_snapshot(snap)
    r = lambda v: None if v is None else round(v * scale, 6)  # noqa: E731
    return {"count": h.count, "mean": r(h.mean), "p50": r(h.quantile(0.5)),
            "p90": r(h.quantile(0.9)), "p99": r(h.quantile(0.99)),
            "min": r(None if h.count == 0 else h.min),
            "max": r(None if h.count == 0 else h.max)}


class MetricsRegistry:
    """Thread-safe counters / gauges / histograms with labels."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[Tuple[str, _LabelKey], float] = {}
        self._gauges: Dict[Tuple[str, _LabelKey], float] = {}
        self._hists: Dict[Tuple[str, _LabelKey], Histogram] = {}

    # -- write paths --------------------------------------------------------
    def inc(self, name: str, value: float = 1, **labels):
        k = _key(name, labels)
        with self._lock:
            self._counters[k] = self._counters.get(k, 0) + value

    def set_gauge(self, name: str, value: float, **labels):
        with self._lock:
            self._gauges[_key(name, labels)] = float(value)

    def observe(self, name: str, value: float, **labels):
        k = _key(name, labels)
        with self._lock:
            h = self._hists.get(k)
            if h is None:
                h = self._hists[k] = Histogram()
            h.observe(value)

    # -- read paths ---------------------------------------------------------
    def counter_value(self, name: str, **labels) -> float:
        with self._lock:
            return self._counters.get(_key(name, labels), 0)

    def labeled(self, name: str, label: str) -> Dict[str, float]:
        """{label value -> counter value} for every counter named
        ``name`` that carries ``label`` (the ByteCounter per-message-type
        view)."""
        out: Dict[str, float] = {}
        with self._lock:
            for (n, lk), v in self._counters.items():
                if n != name:
                    continue
                d = dict(lk)
                if label in d:
                    out[d[label]] = out.get(d[label], 0) + v
        return out

    def quantile(self, name: str, q: float, **labels) -> Optional[float]:
        with self._lock:
            h = self._hists.get(_key(name, labels))
            return h.quantile(q) if h is not None else None

    def histogram_snapshot(self, name: str, **labels) -> Optional[dict]:
        with self._lock:
            h = self._hists.get(_key(name, labels))
            return h.to_snapshot() if h is not None else None

    # -- snapshot / merge ----------------------------------------------------
    def snapshot(self) -> dict:
        with self._lock:
            return {
                "counters": [{"name": n, "labels": dict(lk), "value": v}
                             for (n, lk), v in self._counters.items()],
                "gauges": [{"name": n, "labels": dict(lk), "value": v}
                           for (n, lk), v in self._gauges.items()],
                "histograms": [{"name": n, "labels": dict(lk),
                                **h.to_snapshot()}
                               for (n, lk), h in self._hists.items()],
            }

    def merge(self, snap: dict, extra_labels: Optional[Dict[str, str]] = None):
        """Fold another registry's :meth:`snapshot` in. ``extra_labels``
        relabel the incoming series (e.g. ``{"worker": "3"}``) so merged
        processes stay distinguishable."""
        extra = extra_labels or {}
        with self._lock:
            for e in snap.get("counters", []):
                k = _key(e["name"], {**e.get("labels", {}), **extra})
                self._counters[k] = self._counters.get(k, 0) + e["value"]
            for e in snap.get("gauges", []):
                k = _key(e["name"], {**e.get("labels", {}), **extra})
                self._gauges[k] = e["value"]
            for e in snap.get("histograms", []):
                k = _key(e["name"], {**e.get("labels", {}), **extra})
                h = self._hists.get(k)
                if h is None:
                    h = self._hists[k] = Histogram()
                h.merge(Histogram.from_snapshot(e))


def snapshot_counters(snap: dict, name: str) -> float:
    """Sum of every counter named ``name`` in a snapshot (labels folded)."""
    return sum(e["value"] for e in snap.get("counters", [])
               if e["name"] == name)


def snapshot_histograms(snap: dict, name: str) -> Iterable[dict]:
    return [e for e in snap.get("histograms", []) if e["name"] == name]


def merged_histogram(snaps: Iterable[dict]) -> Histogram:
    h = Histogram()
    for s in snaps:
        h.merge(Histogram.from_snapshot(s))
    return h
