"""Unified observability layer (DESIGN.md §12): metrics registry +
tracing spans + per-iteration telemetry, bundled per run directory.

``Observability`` is the object the solvers take: ``obs=None`` (or the
module-level ``NOOP``) is the disabled fast path — ``span`` returns a
reused null context manager, ``record``/``inc``/``observe`` return
immediately — so instrumented code costs one attribute check when
observability is off, and nothing is ever called from inside jitted
code (host-side boundaries only).

An enabled instance owns a run directory and writes three artifacts:

  * ``telemetry.jsonl`` — one line per solver iteration (streamed);
  * ``metrics.json``    — the registry snapshot at ``finish()``
    (counters, gauges, log-bucket histograms);
  * ``trace.json``      — Chrome-trace/Perfetto events, including any
    worker-process events merged in (one timeline per cluster solve).

``launch/obs_report.py`` reads the directory back and prints the
summary (percentiles, bytes/iter, span hotspots).
"""
from __future__ import annotations

import atexit
import json
import os
import signal
import threading
from typing import Optional

import numpy as np

from repro.obs.context import (          # noqa: F401  (re-exports)
    TraceContext,
    current_context,
    new_trace,
    use_context,
)
from repro.obs.flight import FlightRecorder, load_incident  # noqa: F401
from repro.obs.metrics import (          # noqa: F401  (re-exports)
    Histogram,
    MetricsRegistry,
    merged_histogram,
    snapshot_counters,
    snapshot_histograms,
    summarize_histogram,
)
from repro.obs.scrape import ScrapeServer, render_prometheus  # noqa: F401
from repro.obs.slo import DEFAULT_OBJECTIVES, Objective, SLOTracker  # noqa: F401
from repro.obs.telemetry import TelemetryWriter, jsonable, read_jsonl  # noqa: F401
from repro.obs.trace import (  # noqa: F401
    Tracer,
    is_ancestor,
    load_trace,
    span_hotspots,
    span_tree,
)

TELEMETRY_FILE = "telemetry.jsonl"
METRICS_FILE = "metrics.json"
TRACE_FILE = "trace.json"


class Observability:
    """Registry + tracer + telemetry sink for one run directory."""

    def __init__(self, dir: Optional[str] = None,
                 process_name: str = "main",
                 enabled: Optional[bool] = None,
                 crash_flush: bool = True):
        self.dir = dir
        self.enabled = bool(dir) if enabled is None else bool(enabled)
        self.registry = MetricsRegistry()
        self.tracer = Tracer(enabled=self.enabled,
                             process_name=process_name)
        self.telemetry: Optional[TelemetryWriter] = None
        if self.enabled and dir is not None:
            os.makedirs(dir, exist_ok=True)
            self.telemetry = TelemetryWriter(
                os.path.join(dir, TELEMETRY_FILE))
            if crash_flush:
                self._install_crash_flush()

    @classmethod
    def create(cls, dir: str, process_name: str = "main") -> "Observability":
        return cls(dir=dir, process_name=process_name)

    # -- span / metric front doors (no-ops when disabled) -------------------
    def span(self, name: str, **attrs):
        return self.tracer.span(name, **attrs)

    def inc(self, name: str, value: float = 1, **labels):
        if self.enabled:
            self.registry.inc(name, value, **labels)

    def observe(self, name: str, value: float, **labels):
        if self.enabled:
            self.registry.observe(name, value, **labels)

    def set_gauge(self, name: str, value: float, **labels):
        if self.enabled:
            self.registry.set_gauge(name, value, **labels)

    # -- telemetry -----------------------------------------------------------
    def record(self, **fields):
        if self.telemetry is not None:
            self.telemetry.write(fields)

    def write_history(self, history, tau: Optional[float] = None,
                      rho: Optional[float] = None, start_iter: int = 0,
                      **extra):
        """Stream an :class:`~repro.core.unwrapped.ADMMHistory` (or any
        object with objective/primal_res/dual_res arrays) to the JSONL
        sink — the post-scan path for the fully-jitted drivers, where
        per-iteration host callbacks are off-limits."""
        if self.telemetry is None or history is None:
            return
        obj = np.asarray(history.objective)
        pr = np.asarray(history.primal_res)
        du = np.asarray(history.dual_res)
        gs = (np.asarray(history.grad_sq)
              if getattr(history, "grad_sq", None) is not None else None)
        for i in range(len(obj)):
            rec = {"iter": start_iter + i, "objective": float(obj[i]),
                   "primal_res": float(pr[i]), "dual_res": float(du[i]),
                   "tau": tau, "rho": rho}
            if gs is not None:
                rec["grad_sq"] = float(gs[i])
            rec.update(extra)
            self.record(**rec)

    # -- lifecycle -----------------------------------------------------------
    def _install_crash_flush(self):
        """Crash-safe artifacts (DESIGN.md §16): flush on interpreter
        exit and, when possible, on SIGTERM.

        ``atexit`` covers clean-but-finish()-less exits; the SIGTERM
        hook covers polite kills (it flushes, restores the default
        handler, and re-raises so exit status stays conventional).  Only
        installed from the main thread and only when SIGTERM is still at
        its default — never steals a handler someone else set.  SIGKILL
        cannot be caught; for that case telemetry flushes per line and
        the readers tolerate torn tails (read_jsonl / load_trace).
        """
        atexit.register(self.finish)
        if threading.current_thread() is not threading.main_thread():
            return
        try:
            if signal.getsignal(signal.SIGTERM) is not signal.SIG_DFL:
                return

            def _flush_and_die(signum, frame):
                try:
                    self.finish()
                finally:
                    signal.signal(signum, signal.SIG_DFL)
                    os.kill(os.getpid(), signum)

            signal.signal(signal.SIGTERM, _flush_and_die)
        except (ValueError, OSError):
            pass  # exotic embedding (no signal support): atexit still holds

    def flush(self):
        """Write metrics.json + trace.json NOW without closing the
        telemetry sink — the periodic checkpoint for long-running
        serving processes (finish() remains the closing flush)."""
        if not self.enabled or self.dir is None:
            return
        with open(os.path.join(self.dir, METRICS_FILE), "w") as f:
            json.dump(jsonable(self.registry.snapshot()), f, indent=2)
            f.write("\n")
        self.tracer.export(os.path.join(self.dir, TRACE_FILE))

    def finish(self):
        """Write metrics.json + trace.json and close the JSONL sink.
        Idempotent; a later finish() re-exports the (grown) state."""
        if not self.enabled or self.dir is None:
            return
        self.flush()
        if self.telemetry is not None:
            self.telemetry.close()
            self.telemetry = None
        atexit.unregister(self.finish)


NOOP = Observability(dir=None, enabled=False)
