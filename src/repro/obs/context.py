"""Request-scoped trace context, carried by contextvars and across the wire.

A :class:`TraceContext` names one node in a request's span tree:
``trace_id`` groups every span of one request, ``span_id`` names this
node, ``parent_id`` points at the node that caused it.  The current
context rides a :mod:`contextvars` ContextVar, so it follows the request
through nested spans in one thread for free; crossing a thread or a
process boundary is explicit — the sender serializes ``to_wire()`` into
the frame (transport does this automatically when a context is active)
and the receiver re-activates it with :func:`use_context`.

IDs are short random hex (no central allocator): 16 hex chars for the
trace, 8 for spans.  Collisions within one trace are what matters and at
8 hex chars they are negligible for the span counts a request produces.
"""

from __future__ import annotations

import contextlib
import contextvars
import secrets
from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional

__all__ = [
    "TraceContext",
    "new_trace",
    "current_context",
    "use_context",
]


@dataclass(frozen=True)
class TraceContext:
    trace_id: str
    span_id: str
    parent_id: Optional[str] = None

    def child(self) -> "TraceContext":
        """A new span node under this one (same trace)."""
        return TraceContext(
            trace_id=self.trace_id,
            span_id=secrets.token_hex(4),
            parent_id=self.span_id,
        )

    def to_wire(self) -> Dict[str, Any]:
        """Plain-dict form for embedding in a transport frame."""
        d: Dict[str, Any] = {"trace_id": self.trace_id, "span_id": self.span_id}
        if self.parent_id is not None:
            d["parent_id"] = self.parent_id
        return d

    @classmethod
    def from_wire(cls, d: Any) -> Optional["TraceContext"]:
        """Decode a frame's context field; None for absent/malformed.

        Malformed contexts are dropped, never raised: a bad peer must not
        be able to break request handling by sending garbage trace state.
        """
        if not isinstance(d, dict):
            return None
        tid, sid = d.get("trace_id"), d.get("span_id")
        if not isinstance(tid, str) or not isinstance(sid, str):
            return None
        pid = d.get("parent_id")
        if pid is not None and not isinstance(pid, str):
            pid = None
        return cls(trace_id=tid, span_id=sid, parent_id=pid)


_CURRENT: contextvars.ContextVar[Optional[TraceContext]] = contextvars.ContextVar(
    "repro_trace_context", default=None
)


def new_trace() -> TraceContext:
    """Mint a fresh root context (new trace_id, no parent)."""
    return TraceContext(trace_id=secrets.token_hex(8), span_id=secrets.token_hex(4))


def current_context() -> Optional[TraceContext]:
    """The context active in this thread's execution context, if any."""
    return _CURRENT.get()


@contextlib.contextmanager
def use_context(ctx: Optional[TraceContext]) -> Iterator[Optional[TraceContext]]:
    """Activate ``ctx`` for the dynamic extent of the with-block.

    Accepts None as a no-op so call sites can write
    ``with use_context(maybe_ctx):`` without branching.
    """
    if ctx is None:
        yield None
        return
    token = _CURRENT.set(ctx)
    try:
        yield ctx
    finally:
        _CURRENT.reset(token)
