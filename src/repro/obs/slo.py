"""Declarative SLOs with rolling-window error-budget burn rates.

Objectives are defined over the serving stack's terminal-status taxonomy
(DESIGN.md §15): every decoded fit request ends in exactly one of
ok / degraded / rejected / deadline / error.  An :class:`Objective` says
what fraction of recent requests must be "good"; the tracker keeps a
rolling window of terminal events and evaluates each objective into an
SLI, remaining error budget, and a burn rate:

    burn_rate = (1 - sli) / (1 - target)

1.0 means failures arrive exactly at the sustainable rate (the budget
lasts the window); > 1 means the budget is burning faster than allowed
(the alerting signal); 0 means no failures.  For a target of 1.0 (zero
tolerance, e.g. zero-lost) any failure is an infinite burn, capped at
``BURN_CAP`` to stay JSON/gauge friendly.

Three kinds:
  * ``availability`` — good = status in ``good_statuses`` over all
    terminal events (availability = terminal ok+degraded / decoded);
  * ``latency``      — good = latency <= threshold among events matching
    ``scope`` (warm/cold/all); target 0.99 + threshold X is exactly
    "p99 < X";
  * ``external``     — a boolean invariant fed at evaluation time (the
    zero-lost-requests accounting identity lives in the frontend, not in
    the event stream).

``evaluate`` is pure over the window; ``export_gauges`` mirrors the
results into a registry so the scrape endpoint and metrics.json carry
``slo.sli{objective=...}`` / ``slo.burn_rate{objective=...}`` without
extra plumbing.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

__all__ = ["Objective", "SLOTracker", "DEFAULT_OBJECTIVES", "BURN_CAP"]

BURN_CAP = 1e6


@dataclass(frozen=True)
class Objective:
    name: str
    kind: str                    # "availability" | "latency" | "external"
    target: float                # required good fraction, in (0, 1]
    good_statuses: Tuple[str, ...] = ("ok", "degraded")
    threshold_s: float = 1.0     # latency only
    scope: str = "all"           # latency only: "warm" | "cold" | "all"
    description: str = ""


DEFAULT_OBJECTIVES: Tuple[Objective, ...] = (
    Objective(name="availability", kind="availability", target=0.65,
              description="terminal ok+degraded over decoded requests"),
    Objective(name="warm_latency", kind="latency", target=0.99,
              threshold_s=2.0, scope="warm",
              description="warm-path p99 under threshold"),
    Objective(name="zero_lost", kind="external", target=1.0,
              description="every decoded request got exactly one "
                          "terminal response"),
)


@dataclass
class _Event:
    t: float
    status: str
    latency_s: Optional[float]
    warm: Optional[bool]


@dataclass
class SLOTracker:
    """Rolling window of terminal events + objective evaluation."""

    window_s: float = 600.0
    max_events: int = 100_000
    _events: Deque[_Event] = field(default_factory=deque, repr=False)
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def record(self, status: str, latency_s: Optional[float] = None,
               warm: Optional[bool] = None, t: Optional[float] = None):
        ev = _Event(t=time.monotonic() if t is None else t, status=status,
                    latency_s=latency_s, warm=warm)
        with self._lock:
            self._events.append(ev)
            if len(self._events) > self.max_events:
                self._events.popleft()

    def _window(self, now: Optional[float]) -> List[_Event]:
        now = time.monotonic() if now is None else now
        lo = now - self.window_s
        with self._lock:
            # drop expired events from the left while here (events are
            # appended in time order)
            while self._events and self._events[0].t < lo:
                self._events.popleft()
            return list(self._events)

    def evaluate(self, objectives: Tuple[Objective, ...] = DEFAULT_OBJECTIVES,
                 external: Optional[Dict[str, bool]] = None,
                 now: Optional[float] = None) -> dict:
        """Evaluate each objective over the current window.

        ``external`` supplies the boolean SLI for ``kind="external"``
        objectives by name; an external objective with no supplied value
        evaluates to ok=None (unknown), never a spurious pass/fail.
        """
        events = self._window(now)
        external = external or {}
        out = {"window_s": self.window_s, "events": len(events),
               "objectives": []}
        for obj in objectives:
            out["objectives"].append(self._eval_one(obj, events, external))
        out["ok"] = all(o["ok"] is not False for o in out["objectives"])
        return out

    def _eval_one(self, obj: Objective, events: List[_Event],
                  external: Dict[str, bool]) -> dict:
        res = {"name": obj.name, "kind": obj.kind, "target": obj.target,
               "description": obj.description}
        if obj.kind == "external":
            val = external.get(obj.name)
            if val is None:
                res.update({"sli": None, "burn_rate": None, "ok": None})
                return res
            sli = 1.0 if val else 0.0
            total = good = None
        else:
            if obj.kind == "availability":
                pool = events
                good_of = lambda e: e.status in obj.good_statuses  # noqa: E731
            elif obj.kind == "latency":
                pool = [e for e in events if e.latency_s is not None
                        and (obj.scope == "all"
                             or (obj.scope == "warm" and e.warm is True)
                             or (obj.scope == "cold" and e.warm is False))]
                good_of = lambda e: e.latency_s <= obj.threshold_s  # noqa: E731
            else:
                raise ValueError(f"unknown objective kind: {obj.kind!r}")
            total = len(pool)
            if total == 0:
                res.update({"events": 0, "good": 0, "sli": None,
                            "burn_rate": None, "ok": None})
                return res
            good = sum(1 for e in pool if good_of(e))
            sli = good / total
        budget = 1.0 - obj.target
        bad = 1.0 - sli
        if budget <= 0.0:
            burn = 0.0 if bad <= 0.0 else BURN_CAP
        else:
            burn = min(bad / budget, BURN_CAP)
        res.update({
            "sli": round(sli, 6),
            "burn_rate": round(burn, 4),
            "budget": round(budget, 6),
            "budget_used": round(min(burn, BURN_CAP), 4),
            "ok": sli >= obj.target,
        })
        if total is not None:
            res["events"] = total
            res["good"] = good
        if obj.kind == "latency":
            res["threshold_s"] = obj.threshold_s
            res["scope"] = obj.scope
        return res

    def export_gauges(self, registry, evaluation: Optional[dict] = None,
                      objectives: Tuple[Objective, ...] = DEFAULT_OBJECTIVES,
                      external: Optional[Dict[str, bool]] = None):
        """Mirror an evaluation into ``slo.*`` gauges on ``registry``."""
        ev = evaluation or self.evaluate(objectives, external=external)
        for o in ev["objectives"]:
            if o.get("sli") is not None:
                registry.set_gauge("slo.sli", o["sli"], objective=o["name"])
            if o.get("burn_rate") is not None:
                registry.set_gauge("slo.burn_rate", o["burn_rate"],
                                   objective=o["name"])
            registry.set_gauge(
                "slo.ok",
                1.0 if o["ok"] else (0.0 if o["ok"] is False else -1.0),
                objective=o["name"])
        return ev
