"""Live scrape endpoint: /metrics, /healthz, /slo over stdlib http.server.

PR 6's observability writes artifacts when a run *finishes*; a serving
frontend runs forever, so this module exposes the same
:class:`~repro.obs.metrics.MetricsRegistry` snapshot while the process
is alive.  :class:`ScrapeServer` owns a ``ThreadingHTTPServer`` on a
daemon thread and renders whatever snapshot the supplied callable
returns at request time — it works over any registry, including
cluster-merged ones, and adds no dependencies.

Routes:
  * ``/metrics``       Prometheus text exposition (version 0.0.4)
  * ``/metrics.json``  the raw snapshot dict (exact histograms included)
  * ``/healthz``       liveness JSON from ``health_fn``
  * ``/slo``           SLO evaluation JSON from ``slo_fn``

Rendering notes: counter/gauge names are sanitized to the Prometheus
grammar (dots become underscores); histograms are rendered as summaries
(quantile series + ``_sum``/``_count``) because the registry's
log-spaced buckets already bound quantile error and a summary keeps the
exposition small.  The JSON route carries the lossless form.
"""
from __future__ import annotations

import json
import math
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, Optional, Tuple

from repro.obs.metrics import Histogram

__all__ = ["ScrapeServer", "render_prometheus"]

_NAME_OK = re.compile(r"[^a-zA-Z0-9_:]")
_QUANTILES = (0.5, 0.9, 0.99)


def _metric_name(name: str) -> str:
    name = _NAME_OK.sub("_", name)
    if not name or name[0].isdigit():
        name = "_" + name
    return name


def _label_str(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    parts = []
    for k in sorted(labels):
        v = str(labels[k]).replace("\\", r"\\").replace('"', r"\"")
        v = v.replace("\n", r"\n")
        parts.append(f'{_metric_name(str(k))}="{v}"')
    return "{" + ",".join(parts) + "}"


def _num(v: Any) -> str:
    try:
        f = float(v)
    except (TypeError, ValueError):
        return "NaN"
    if math.isnan(f):
        return "NaN"
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def render_prometheus(snapshot: dict) -> str:
    """Render a MetricsRegistry snapshot as Prometheus text exposition."""
    lines = []
    typed = set()

    def _type_line(name: str, kind: str):
        if name not in typed:
            typed.add(name)
            lines.append(f"# TYPE {name} {kind}")

    # exposition format requires all samples of one metric contiguous
    def _by_name(entries):
        return sorted(entries, key=lambda e: e["name"])

    for e in _by_name(snapshot.get("counters", [])):
        name = _metric_name(e["name"]) + "_total"
        _type_line(name, "counter")
        lines.append(f"{name}{_label_str(e.get('labels', {}))} "
                     f"{_num(e.get('value', 0))}")
    for e in _by_name(snapshot.get("gauges", [])):
        name = _metric_name(e["name"])
        _type_line(name, "gauge")
        lines.append(f"{name}{_label_str(e.get('labels', {}))} "
                     f"{_num(e.get('value', 0))}")
    for e in _by_name(snapshot.get("histograms", [])):
        name = _metric_name(e["name"])
        _type_line(name, "summary")
        labels = e.get("labels", {})
        h = Histogram.from_snapshot(e)
        for q in _QUANTILES:
            ql = dict(labels)
            ql["quantile"] = str(q)
            v = h.quantile(q)
            lines.append(f"{name}{_label_str(ql)} "
                         f"{_num(v if v is not None else float('nan'))}")
        lines.append(f"{name}_sum{_label_str(labels)} {_num(h.sum)}")
        lines.append(f"{name}_count{_label_str(labels)} {_num(h.count)}")
    return "\n".join(lines) + "\n"


class ScrapeServer:
    """Introspection HTTP listener on a daemon thread.

    ``snapshot_fn`` is called per scrape and must return a registry
    snapshot dict; ``health_fn``/``slo_fn`` return JSON-able dicts.
    Callbacks run on the HTTP thread — they must be cheap and
    thread-safe (registry snapshots are).  A callback that raises turns
    into a 500 with the error text rather than killing the thread.
    """

    def __init__(self, snapshot_fn: Callable[[], dict],
                 health_fn: Optional[Callable[[], dict]] = None,
                 slo_fn: Optional[Callable[[], dict]] = None,
                 host: str = "127.0.0.1", port: int = 0):
        self._snapshot_fn = snapshot_fn
        self._health_fn = health_fn or (lambda: {"status": "ok"})
        self._slo_fn = slo_fn or (lambda: {})
        outer = self

        class _Handler(BaseHTTPRequestHandler):
            def log_message(self, *a):  # keep scrapes out of stderr
                pass

            def do_GET(self):
                try:
                    body, ctype = outer._route(self.path)
                except Exception as e:  # noqa: BLE001 — report, don't die
                    payload = json.dumps({"error": str(e)}).encode()
                    self._reply(500, payload, "application/json")
                    return
                if body is None:
                    self._reply(404, b'{"error": "not found"}',
                                "application/json")
                    return
                self._reply(200, body, ctype)

            def _reply(self, code: int, body: bytes, ctype: str):
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="obs-scrape", daemon=True)
        self._thread.start()

    def _route(self, path: str) -> Tuple[Optional[bytes], str]:
        path = path.split("?", 1)[0]
        if path == "/metrics":
            return (render_prometheus(self._snapshot_fn()).encode(),
                    "text/plain; version=0.0.4; charset=utf-8")
        if path == "/metrics.json":
            return (json.dumps(self._snapshot_fn()).encode(),
                    "application/json")
        if path == "/healthz":
            return json.dumps(self._health_fn()).encode(), "application/json"
        if path == "/slo":
            return json.dumps(self._slo_fn()).encode(), "application/json"
        return None, ""

    @property
    def address(self) -> Tuple[str, int]:
        return self._httpd.server_address[:2]

    def url(self, path: str = "/metrics") -> str:
        host, port = self.address
        return f"http://{host}:{port}{path}"

    def close(self):
        self._httpd.shutdown()
        self._thread.join(timeout=5.0)
        self._httpd.server_close()
