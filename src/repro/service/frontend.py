"""Networked multi-tenant front end for the FitServer (DESIGN.md §15).

The paper's global sub-problem is cheap enough that ONE node can answer
fits over massive data — so the serving story is a single shared
:class:`~repro.service.server.FitServer` (cached Gram stats, micro-batch
coalescing) behind a threaded TCP front end speaking the cluster
runtime's length-prefixed framing (:mod:`repro.cluster.transport`).

The design goal is *degrade instead of fail*; every request admitted
past the framing layer receives exactly one terminal response:

  ``ok``        solved (warm from cached stats, or cold within budget)
  ``degraded``  cold budget blown / breaker open → best warm/cached
                answer (a ridge fit from the dataset's Gram stats)
  ``deadline``  the request's deadline expired while still queued
  ``rejected``  admission control said no (tenant quota / queue bound),
                with a retry-after hint
  ``error``     the request itself was bad (unknown fingerprint,
                missing mu/b, stats-only dataset needing raw rows) or
                the backend failed on it

Failure containment: each client connection gets its own handler
thread; a crashed, slow-loris, or byte-corrupting client is severed at
the transport layer (frame deadline / frame cap / undecodable frame —
see ``Listener``'s per-accept knobs) without touching any sibling
tenant's connection, and its undeliverable responses are accounted, not
lost. A failing or budget-blowing cold-solve backend trips the
:class:`~repro.service.admission.CircuitBreaker` and subsequent cold
requests shed to degraded answers instead of piling onto a dead pool.

Chaos: a :class:`~repro.cluster.chaos.FaultInjector` built over
``SERVICE_DATA_PLANE`` frame types can be handed to the front end — its
wire faults ride ``Connection.send`` on accepted connections (via
``Listener``), and its ``slow`` process faults stall the cold-solve
backend, which is how the load benchmark proves the degrade path.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.cluster.chaos import FaultInjector
from repro.cluster.transport import (
    ByteCounter,
    Connection,
    ConnectionClosed,
    Listener,
    connect,
)
from repro.obs import Observability
from repro.obs.context import TraceContext, current_context, new_trace, \
    use_context
from repro.obs.flight import FlightRecorder
from repro.obs.flight import NOOP as FLIGHT_NOOP
from repro.obs.metrics import MetricsRegistry
from repro.obs.scrape import ScrapeServer
from repro.obs.slo import DEFAULT_OBJECTIVES, Objective, SLOTracker
from repro.obs.trace import Tracer
from repro.service import registry
from repro.service.admission import AdmissionController, CircuitBreaker
from repro.service.server import FitRequest, FitResponse, FitServer

#: frame types the service treats as chaos-injectable data plane
SERVICE_DATA_PLANE = ("fit", "fit_result")

#: terminal response statuses (DESIGN.md §15 taxonomy)
TERMINAL_STATUSES = ("ok", "degraded", "deadline", "rejected", "error")


class _Pending:
    """One admitted fit awaiting its terminal response. ``respond`` is
    exactly-once: the first caller wins, later callers (e.g. a cold
    future completing after its budget already answered ``degraded``)
    are no-ops — this is what makes "every request gets exactly one
    terminal response" a structural property rather than a hope."""

    __slots__ = ("req", "tenant", "rid", "conn", "deadline", "enqueue_t",
                 "enqueue_wall_us", "ctx", "_done", "_lock")

    def __init__(self, req: FitRequest, tenant: str, rid: int,
                 conn: Connection, deadline: Optional[float],
                 ctx: Optional[TraceContext] = None):
        self.req = req
        self.tenant = tenant
        self.rid = rid
        self.conn = conn
        self.deadline = deadline          # absolute monotonic, or None
        self.enqueue_t = time.monotonic()
        self.enqueue_wall_us = time.time_ns() // 1000
        self.ctx = ctx                    # request's wire TraceContext
        self._done = False
        self._lock = threading.Lock()

    def claim(self) -> bool:
        with self._lock:
            if self._done:
                return False
            self._done = True
            return True


class FitFrontend:
    """Threaded TCP front end over one shared :class:`FitServer`.

    Threads: one acceptor, one handler per live connection, one solver
    (micro-batch flush + deadline sweep + cold-future polling), plus a
    small cold-solve pool. All request admission and response delivery
    is exactly-once under ``_cv``/per-pending locks.
    """

    def __init__(self, server: Optional[FitServer] = None,
                 host: str = "127.0.0.1", port: int = 0, *,
                 window: int = 16, flush_interval_s: float = 0.01,
                 max_queue: int = 256,
                 tenant_rate: Optional[float] = None,
                 tenant_burst: Optional[float] = None,
                 default_deadline_s: float = 30.0,
                 cold_budget_s: Optional[float] = None,
                 cold_workers: int = 2,
                 breaker_threshold: int = 3,
                 breaker_reset_s: float = 5.0,
                 idle_timeout_s: float = 60.0,
                 frame_deadline_s: float = 5.0,
                 max_frame_bytes: int = 64 << 20,
                 chaos: Optional[FaultInjector] = None,
                 obs: Optional[Observability] = None,
                 scrape_port: Optional[int] = None,
                 slo_objectives: Optional[Sequence[Objective]] = None,
                 slo_window_s: float = 600.0,
                 flight: Optional[FlightRecorder] = None):
        self.server = server or FitServer(window=window)
        self.window = int(window)
        self.flush_interval_s = float(flush_interval_s)
        self.default_deadline_s = float(default_deadline_s)
        self.cold_budget_s = cold_budget_s
        self.chaos = chaos
        # Live observability plane (DESIGN.md §16). The metrics registry
        # is ALWAYS real — status_counts()/zero_lost_requests() are
        # service accounting, not optional telemetry — but when an
        # enabled Observability is handed in, the service counts into
        # ITS registry so metrics.json / the scrape endpoint carry the
        # serving series, and its tracer records the request spans.
        self.obs = obs
        if obs is not None:
            self.metrics = obs.registry
            self.tracer = obs.tracer
        else:
            self.metrics = MetricsRegistry()
            self.tracer = Tracer(enabled=False)
        if flight is not None:
            self.flight = flight
        elif obs is not None and obs.enabled and obs.dir is not None:
            self.flight = FlightRecorder(
                dir=os.path.join(obs.dir, "incidents"),
                process_name="frontend")
        else:
            self.flight = FLIGHT_NOOP
        self.slo = SLOTracker(window_s=slo_window_s)
        self.slo_objectives: Tuple[Objective, ...] = (
            tuple(slo_objectives) if slo_objectives is not None
            else DEFAULT_OBJECTIVES)
        self.admission = AdmissionController(
            max_queue=max_queue, tenant_rate=tenant_rate,
            tenant_burst=tenant_burst, registry=self.metrics)
        self.breaker = CircuitBreaker(failure_threshold=breaker_threshold,
                                      reset_after_s=breaker_reset_s)
        self.counter = ByteCounter(self.metrics)
        self.listener = Listener(host, port, chaos=chaos,
                                 max_frame_bytes=max_frame_bytes,
                                 frame_deadline_s=frame_deadline_s)
        self.address: Tuple[str, int] = self.listener.address
        self.idle_timeout_s = float(idle_timeout_s)
        self._t_start = time.monotonic()
        # live scrape endpoint (/metrics, /healthz, /slo) — optional;
        # port 0 asks the OS for one (see self.scrape.address)
        self.scrape: Optional[ScrapeServer] = None
        if scrape_port is not None:
            self.scrape = ScrapeServer(
                snapshot_fn=self.metrics_snapshot,
                health_fn=self.health,
                slo_fn=self.slo_snapshot,
                host=host, port=int(scrape_port))

        self._cv = threading.Condition()
        self._pending: List[_Pending] = []
        self._cold_inflight: List[Tuple[_Pending, object,
                                        Optional[float]]] = []
        self._conns: Dict[int, Connection] = {}
        self._conn_ids = itertools.count()
        self._fit_seq = 0
        self._stop = threading.Event()
        self._cold_pool = ThreadPoolExecutor(
            max_workers=cold_workers, thread_name_prefix="cold-solve")
        self._threads = [
            threading.Thread(target=self._accept_loop, daemon=True,
                             name="svc-accept"),
            threading.Thread(target=self._solve_loop, daemon=True,
                             name="svc-solver"),
        ]
        for t in self._threads:
            t.start()

    # -- connection plane ----------------------------------------------------
    def _accept_loop(self):
        while not self._stop.is_set():
            try:
                conn = self.listener.accept(timeout=0.2,
                                            counter=self.counter)
            except OSError:
                return                    # listener closed under us
            if conn is None:
                continue
            cid = next(self._conn_ids)
            with self._cv:
                self._conns[cid] = conn
            threading.Thread(target=self._handle, args=(conn, cid),
                             daemon=True, name=f"svc-conn-{cid}").start()

    def _handle(self, conn: Connection, cid: int):
        """Per-connection receive loop. Any transport-level failure on
        THIS connection severs THIS connection only; its queued requests
        stay pending and their responses are recorded undeliverable."""
        reason = "eof"
        try:
            while not self._stop.is_set():
                msg = conn.recv(timeout=self.idle_timeout_s)
                if msg is None:           # idle — keep the session open
                    continue
                self._dispatch_msg(conn, msg)
        except ConnectionClosed as e:
            reason = "eof" if "EOF" in str(e) else "protocol"
        finally:
            self.metrics.inc("service.conn_closed", reason=reason)
            if reason != "eof":
                self.metrics.inc("service.severed")
            conn.close()
            with self._cv:
                self._conns.pop(cid, None)

    def _dispatch_msg(self, conn: Connection, msg: dict):
        mtype = msg.get("type")
        rid = msg.get("rid", 0)
        tenant = str(msg.get("tenant", "?"))
        if mtype == "fit":
            self._admit_fit(conn, msg, rid, tenant)
        elif mtype == "register":
            self._reply(conn, "registered", rid, lambda: {
                "fingerprint": self.server.register_dataset(
                    np.asarray(msg["D"]),
                    None if msg.get("b") is None else np.asarray(msg["b"]),
                    keep_data=bool(msg.get("keep_data", True)))})
        elif mtype == "ingest":
            self._reply(conn, "ingested", rid, lambda: {
                "fingerprint": self.server.ingest_block(
                    msg["fingerprint"], np.asarray(msg["D"]),
                    None if msg.get("b") is None
                    else np.asarray(msg["b"]))})
        elif mtype == "retire":
            self._reply(conn, "retired", rid, lambda: {
                "fingerprint": self.server.retire_block(
                    msg["fingerprint"], np.asarray(msg["D"]),
                    None if msg.get("b") is None
                    else np.asarray(msg["b"]))})
        elif mtype == "counters":
            self._reply(conn, "counters_result", rid, lambda: {
                "server": self.server.counters.snapshot(),
                "admission": self.admission.snapshot(),
                "breaker": self.breaker.snapshot(),
                "frontend": self.status_counts(),
                "slo": self.slo_snapshot(),
                "flight": self.flight.snapshot()})
        elif mtype == "ping":
            self._safe_send(conn, "pong", rid=rid)
        else:
            self._safe_send(conn, "error_reply", rid=rid,
                            error=f"unknown message type {mtype!r}")

    def _reply(self, conn: Connection, ok_type: str, rid: int, fn):
        """Run a synchronous admin op; errors become error replies for
        THIS request instead of killing the connection."""
        try:
            payload = fn()
        except Exception as e:            # noqa: BLE001 — containment
            self._safe_send(conn, "error_reply", rid=rid,
                            error=f"{type(e).__name__}: {e}")
            return
        self._safe_send(conn, ok_type, rid=rid, **payload)

    def _safe_send(self, conn: Connection, mtype: str, **payload) -> bool:
        try:
            conn.send(mtype, **payload)
            return True
        except (ConnectionClosed, OSError):
            self.metrics.inc("service.undeliverable")
            return False

    # -- admission -----------------------------------------------------------
    def _admit_fit(self, conn: Connection, msg: dict, rid: int,
                   tenant: str):
        # Re-activate the request's wire TraceContext (if the client
        # sent one) for the dynamic extent of the admission decision:
        # the admit span becomes a child of the client's span, and the
        # context rides the _Pending into queue-wait / solve spans.
        ctx = TraceContext.from_wire(msg.get("_ctx"))
        with use_context(ctx):
            with self.tracer.span("frontend.admit", tenant=tenant,
                                  rid=rid):
                self._admit_fit_inner(conn, msg, rid, tenant, ctx)

    def _admit_fit_inner(self, conn: Connection, msg: dict, rid: int,
                         tenant: str, ctx: Optional[TraceContext]):
        self.metrics.inc("service.fit_seen", tenant=tenant)
        with self._cv:
            in_flight = len(self._pending) + len(self._cold_inflight)
        adm = self.admission.admit(tenant, in_flight)
        if not adm.ok:
            self.metrics.inc("service.responses", status="rejected")
            self.metrics.inc("service.rejected", reason=adm.reason)
            self.slo.record("rejected")
            self.tracer.instant("frontend.rejected", tenant=tenant,
                                reason=adm.reason)
            self.flight.note("reject", tenant=tenant, rid=rid,
                             reason=adm.reason)
            self._safe_send(conn, "fit_result", rid=rid,
                            status="rejected", x=None, iters=0,
                            batch_size=0, from_cache=False,
                            error=adm.reason,
                            retry_after_s=adm.retry_after_s)
            return
        req = FitRequest(
            problem=str(msg["problem"]), fingerprint=str(msg["fingerprint"]),
            b=None if msg.get("b") is None else np.asarray(msg["b"]),
            mu=msg.get("mu"), l2=float(msg.get("l2", 0.0)),
            C=float(msg.get("C", 1.0)), delta=float(msg.get("delta", 1.0)),
            iters=int(msg.get("iters", 1000)))
        dl = msg.get("deadline_s", None)
        dl = self.default_deadline_s if dl is None else float(dl)
        deadline = (time.monotonic() + dl) if dl > 0 else None
        p = _Pending(req, tenant, rid, conn, deadline, ctx=ctx)
        self.flight.note("admit", tenant=tenant, rid=rid,
                         problem=req.problem)
        with self._cv:
            self._fit_seq += 1
            if self.chaos is not None:
                self.chaos.set_iteration(self._fit_seq)
            self._pending.append(p)
            self._cv.notify()

    # -- response plane ------------------------------------------------------
    def _respond(self, p: _Pending, status: str,
                 x: Optional[np.ndarray] = None, iters: int = 0,
                 batch_size: int = 1, from_cache: bool = False,
                 error: Optional[str] = None,
                 retry_after_s: Optional[float] = None) -> bool:
        if not p.claim():
            return False
        latency_s = time.monotonic() - p.enqueue_t
        warm = p.req.problem in registry.GRAM_SOLVERS
        self.metrics.inc("service.responses", status=status)
        self.metrics.observe("service.queue_wait_s", latency_s)
        self.slo.record(status, latency_s=latency_s, warm=warm)
        self.flight.note("respond", status=status, tenant=p.tenant,
                         rid=p.rid, latency_s=round(latency_s, 6),
                         **({"trace_id": p.ctx.trace_id}
                            if p.ctx is not None else {}))
        # the terminal frame carries the request context back (p.ctx
        # re-activated so transport stamps _ctx; solver thread has none)
        with use_context(p.ctx):
            self._safe_send(p.conn, "fit_result", rid=p.rid, status=status,
                            x=None if x is None else np.asarray(x),
                            iters=int(iters), batch_size=int(batch_size),
                            from_cache=bool(from_cache), error=error,
                            retry_after_s=retry_after_s)
        if status in ("error", "deadline"):
            # post-incident debugging trigger (DESIGN.md §16): dump the
            # flight ring around any request that died
            self.flight.incident(
                f"status_{status}", tenant=p.tenant, rid=p.rid,
                error=error,
                **({"trace_id": p.ctx.trace_id}
                   if p.ctx is not None else {}))
        return True

    def _respond_from(self, p: _Pending, r: FitResponse):
        self._respond(p, r.status, x=r.x, iters=r.iters,
                      batch_size=r.batch_size, from_cache=r.from_cache,
                      error=r.error)

    def _respond_degraded(self, p: _Pending, why: str):
        """Best warm/cached answer: a ridge fit straight from the
        dataset's Gram stats (zero data passes when the factor is live).
        Mirrors the cluster DegradePolicy semantics — an explicit,
        bounded-quality answer instead of an unbounded wait."""
        fb = FitRequest(problem="ridge", fingerprint=p.req.fingerprint,
                        b=p.req.b,
                        mu=p.req.mu if p.req.mu is not None else 1.0,
                        iters=1)
        self.flight.note("degrade", tenant=p.tenant, rid=p.rid, why=why,
                         **({"trace_id": p.ctx.trace_id}
                            if p.ctx is not None else {}))
        try:
            with use_context(p.ctx):
                with self.tracer.span("frontend.degrade", why=why,
                                      tenant=p.tenant):
                    r = self.server.solve_one(fb)
            if r.status != "ok":
                raise RuntimeError(r.error or "fallback failed")
            self.metrics.inc("service.degraded", why=why)
            self._respond(p, "degraded", x=r.x, iters=r.iters,
                          from_cache=True, error=why)
        except Exception as e:            # noqa: BLE001 — containment
            self._respond(p, "error",
                          error=f"{why}; degraded fallback failed: {e}")

    # -- solver loop ---------------------------------------------------------
    def _solve_loop(self):
        while not self._stop.is_set():
            with self._cv:
                if not self._pending and not self._cold_inflight:
                    self._cv.wait(timeout=0.05)
                now = time.monotonic()
                expired = [p for p in self._pending
                           if p.deadline is not None and now > p.deadline]
                for p in expired:
                    self._pending.remove(p)
                batch: List[_Pending] = []
                if self._pending and (
                        len(self._pending) >= self.window
                        or now - self._pending[0].enqueue_t
                        >= self.flush_interval_s):
                    batch = self._pending[:self.window]
                    del self._pending[:len(batch)]
            for p in expired:
                self.metrics.inc("service.deadline_expired", where="queue")
                self._respond(p, "deadline",
                              error="deadline expired in queue")
            if batch:
                self._dispatch_batch(batch)
            polled = self._poll_cold()
            if not (expired or batch or polled):
                # work exists but is not actionable yet (window filling,
                # cold futures running): don't spin the CPU against it
                time.sleep(0.002)
        # shutdown: drain everything still pending with explicit errors —
        # a stopping service must not strand a single request
        with self._cv:
            leftovers = self._pending[:]
            self._pending.clear()
            cold = self._cold_inflight[:]
            self._cold_inflight = []
        for p in leftovers:
            self._respond(p, "error", error="service shutting down")
        for p, _fut, _dl in cold:
            self._respond(p, "error", error="service shutting down")

    def _dispatch_batch(self, batch: List[_Pending]):
        # close out each request's queue-wait interval: a retroactive
        # span (nobody was "in" it) parented under the request context,
        # plus the dispatch_wait histogram the trace tests reconcile
        now = time.monotonic()
        for p in batch:
            wait_s = now - p.enqueue_t
            self.metrics.observe("service.dispatch_wait_s", wait_s)
            self.tracer.complete_at("frontend.queue_wait",
                                    p.enqueue_wall_us, wait_s,
                                    ctx=p.ctx, tenant=p.tenant)
        warm = [p for p in batch if p.req.problem in registry.GRAM_SOLVERS]
        cold = [p for p in batch if p.req.problem not in
                registry.GRAM_SOLVERS]
        if warm:
            resps: List[FitResponse] = []
            with self.tracer.span("frontend.warm_flush",
                                  batch=len(warm)):
                for p in warm:
                    resps.extend(self.server.submit(p.req))
                resps.extend(self.server.flush())
            by_id = {r.request_id: r for r in resps}
            for p in warm:
                r = by_id.get(p.req.request_id)
                if r is None:             # structurally unreachable; keep
                    self._respond(p, "error",  # the invariant anyway
                                  error="response lost in flush")
                else:
                    self._respond_from(p, r)
        for p in cold:
            self._dispatch_cold(p)

    def _dispatch_cold(self, p: _Pending):
        if not self.breaker.allow():
            self.metrics.inc("service.breaker_shed")
            self._respond_degraded(p, "circuit breaker open")
            return
        budget = None
        if p.deadline is not None:
            budget = p.deadline
        if self.cold_budget_s is not None:
            b = time.monotonic() + self.cold_budget_s
            budget = b if budget is None else min(budget, b)
        fut = self._cold_pool.submit(self._cold_solve, p.req, p.ctx)
        with self._cv:
            self._cold_inflight.append((p, fut, budget))

    def _cold_solve(self, req: FitRequest,
                    ctx: Optional[TraceContext] = None) -> FitResponse:
        # contextvars do not follow work into pool threads, so the
        # request context is passed explicitly and re-activated here;
        # the executor span (chaos stall included — the timeline should
        # SHOW the injected slowness) chains under the client's span.
        with use_context(ctx):
            with self.tracer.span("frontend.cold_solve",
                                  problem=req.problem):
                if self.chaos is not None:
                    for kind, param in self.chaos.process_actions(
                            self._fit_seq):
                        if kind == "slow":
                            time.sleep(param / 1e3)
                return self.server.solve_one(req)

    def _poll_cold(self) -> int:
        with self._cv:
            now = time.monotonic()
            done, timed_out, still = [], [], []
            for entry in self._cold_inflight:
                p, fut, budget = entry
                if fut.done():
                    done.append((p, fut))
                elif budget is not None and now > budget:
                    timed_out.append(p)   # future keeps running; its
                    # eventual result loses the respond race by design
                else:
                    still.append(entry)
            self._cold_inflight = still
        for p, fut in done:
            try:
                r = fut.result()
                self.breaker.record_success()
                self._respond_from(p, r)
            except (KeyError, ValueError) as e:
                # the REQUEST was bad — not a backend failure, so the
                # breaker stays untouched
                self._respond(p, "error", error=f"{type(e).__name__}: {e}")
            except Exception as e:        # noqa: BLE001 — backend failure
                self._breaker_failure(why=f"{type(e).__name__}: {e}")
                self.metrics.inc("service.cold_failures")
                self._respond(p, "error", error=f"{type(e).__name__}: {e}")
        for p in timed_out:
            self._breaker_failure(why="cold budget blown")
            self.metrics.inc("service.cold_budget_blown")
            self._respond_degraded(p, "cold solve blew its budget")
        return len(done) + len(timed_out)

    def _breaker_failure(self, why: str):
        """Record a cold-backend failure; a closed→open transition (a
        trip) is an incident trigger — dump the flight ring."""
        before = self.breaker.trips
        self.breaker.record_failure()
        if self.breaker.trips > before:
            self.metrics.inc("service.breaker_trips")
            self.tracer.instant("breaker.trip", why=why)
            self.flight.note("breaker", state="open", why=why)
            self.flight.incident("breaker_trip", why=why,
                                 failures=self.breaker.failure_threshold)

    # -- observability / lifecycle -------------------------------------------
    def metrics_snapshot(self) -> dict:
        """One merged registry snapshot for the scrape endpoint: the
        service/admission series, the shared FitServer's ``server.*``
        series, live gauges (queue depth, breaker, connections), and the
        current SLO gauges — what a Prometheus scrape should see."""
        reg = MetricsRegistry()
        reg.merge(self.metrics.snapshot())
        if self.server.counters.registry is not self.metrics:
            reg.merge(self.server.counters.registry.snapshot())
        with self._cv:
            reg.set_gauge("service.queue_depth", len(self._pending))
            reg.set_gauge("service.cold_inflight", len(self._cold_inflight))
            reg.set_gauge("service.connections", len(self._conns))
        for tenant, tokens in self.admission.bucket_levels().items():
            reg.set_gauge("admission.tokens", tokens, tenant=tenant)
        b = self.breaker.snapshot()
        reg.set_gauge("breaker.open", 1.0 if b["state"] == "open" else 0.0)
        reg.set_gauge("breaker.failures", b["failures"])
        reg.set_gauge("breaker.trips", b["trips"])
        reg.set_gauge("service.uptime_s",
                      round(time.monotonic() - self._t_start, 3))
        self.slo.export_gauges(reg, objectives=self.slo_objectives,
                               external={"zero_lost":
                                         self.zero_lost_requests()})
        return reg.snapshot()

    def health(self) -> dict:
        """Liveness summary for /healthz."""
        with self._cv:
            in_flight = len(self._pending) + len(self._cold_inflight)
            conns = len(self._conns)
        return {
            "status": "stopping" if self._stop.is_set() else "ok",
            "address": list(self.address),
            "uptime_s": round(time.monotonic() - self._t_start, 3),
            "in_flight": in_flight,
            "connections": conns,
            "breaker": self.breaker.snapshot(),
            "admission": self.admission.snapshot(),
        }

    def slo_snapshot(self) -> dict:
        """Current SLO evaluation (rolling window) for /slo."""
        return self.slo.evaluate(
            self.slo_objectives,
            external={"zero_lost": self.zero_lost_requests()})

    def status_counts(self) -> Dict[str, int]:
        """{terminal status -> count} plus bookkeeping totals."""
        out = {s: int(v) for s, v in
               self.metrics.labeled("service.responses", "status").items()}
        out["fit_seen"] = int(sum(
            self.metrics.labeled("service.fit_seen", "tenant").values()))
        out["undeliverable"] = int(
            self.metrics.counter_value("service.undeliverable"))
        out["severed"] = int(
            self.metrics.counter_value("service.severed"))
        with self._cv:
            out["in_flight"] = (len(self._pending)
                                + len(self._cold_inflight))
        return out

    def zero_lost_requests(self) -> bool:
        """Every decoded fit request has exactly one terminal response
        and nothing is still queued — the service-side half of the
        zero-lost invariant (the client-side half is each healthy
        tenant's submitted == received accounting)."""
        c = self.status_counts()
        responded = sum(c.get(s, 0) for s in TERMINAL_STATUSES)
        return c["in_flight"] == 0 and responded == c["fit_seen"]

    def close(self):
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5.0)
        if self.scrape is not None:
            self.scrape.close()
        self.listener.close()
        with self._cv:
            conns = list(self._conns.values())
            self._conns.clear()
        for c in conns:
            c.close()
        self._cold_pool.shutdown(wait=False)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


# ---------------------------------------------------------------------------
# client
# ---------------------------------------------------------------------------

class FitServiceClient:
    """Blocking client for one tenant. Requests are rid-tagged; replies
    arriving out of order (sibling requests coalesced into different
    micro-batches) are buffered until their caller asks. ``fit_async``/
    ``result`` expose the pipelined form the load generator uses."""

    def __init__(self, address: Tuple[str, int], tenant: str = "t0",
                 timeout: float = 10.0, chaos=None, retries: int = 2,
                 tracer: Optional[Tracer] = None):
        self.conn = connect(address, timeout=timeout, chaos=chaos,
                            retries=retries)
        self.tenant = tenant
        # optional client-side tracer: each fit mints a TraceContext and
        # records a client span; transport ships the context in-frame so
        # the frontend's spans chain under it (DESIGN.md §16)
        self.tracer = tracer
        self._rid = itertools.count(1)
        self._buffer: Dict[int, dict] = {}

    def _traced(self) -> bool:
        return self.tracer is not None and self.tracer.enabled

    def _send(self, mtype: str, **payload) -> int:
        rid = next(self._rid)
        self.conn.send(mtype, rid=rid, tenant=self.tenant, **payload)
        return rid

    def result(self, rid: int, timeout: float = 30.0) -> dict:
        if rid in self._buffer:
            return self._buffer.pop(rid)
        deadline = time.monotonic() + timeout
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(
                    f"no reply for rid {rid} within {timeout}s")
            msg = self.conn.recv(timeout=remaining)
            if msg is None:
                continue
            if msg.get("rid") == rid:
                return msg
            self._buffer[msg["rid"]] = msg

    # -- ops ----------------------------------------------------------------
    def register(self, D, b=None, keep_data: bool = True,
                 timeout: float = 60.0) -> str:
        rid = self._send("register", D=np.asarray(D),
                         b=None if b is None else np.asarray(b),
                         keep_data=keep_data)
        msg = self.result(rid, timeout=timeout)
        if msg["type"] != "registered":
            raise RuntimeError(msg.get("error", "register failed"))
        return msg["fingerprint"]

    def ingest(self, fingerprint: str, D, b=None,
               timeout: float = 60.0) -> str:
        rid = self._send("ingest", fingerprint=fingerprint,
                         D=np.asarray(D),
                         b=None if b is None else np.asarray(b))
        msg = self.result(rid, timeout=timeout)
        if msg["type"] != "ingested":
            raise RuntimeError(msg.get("error", "ingest failed"))
        return msg["fingerprint"]

    def fit_async(self, problem: str, fingerprint: str, *, b=None,
                  mu=None, l2: float = 0.0, C: float = 1.0,
                  delta: float = 1.0, iters: int = 1000,
                  deadline_s: Optional[float] = None) -> int:
        send = lambda: self._send(  # noqa: E731
            "fit", problem=problem, fingerprint=fingerprint,
            b=None if b is None else np.asarray(b), mu=mu,
            l2=l2, C=C, delta=delta, iters=iters, deadline_s=deadline_s)
        if not self._traced():
            return send()
        # mint a trace unless the caller already opened one (sync fit()
        # wraps this in a request-spanning client span)
        mint = current_context() is None
        with use_context(new_trace() if mint else None):
            with self.tracer.span("client.submit", tenant=self.tenant,
                                  problem=problem):
                return send()

    def fit(self, problem: str, fingerprint: str,
            timeout: float = 30.0, **kw) -> dict:
        if not self._traced():
            rid = self.fit_async(problem, fingerprint, **kw)
            return self.result(rid, timeout=timeout)
        # one client span covering submit → terminal response; the span's
        # context crosses the wire inside the fit frame, so every
        # frontend/executor span of this request is its descendant
        with use_context(new_trace()):
            with self.tracer.span("client.fit", tenant=self.tenant,
                                  problem=problem):
                rid = self.fit_async(problem, fingerprint, **kw)
                return self.result(rid, timeout=timeout)

    def counters(self, timeout: float = 10.0) -> dict:
        return self.result(self._send("counters"), timeout=timeout)

    def ping(self, timeout: float = 10.0) -> bool:
        return self.result(self._send("ping"),
                           timeout=timeout)["type"] == "pong"

    def close(self):
        self.conn.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
