"""Sufficient statistics as the unit of serving (paper §4, productionized).

Transpose reduction collapses a tall dataset D (m x n, m >> n) into
G = D^T D and c = D^T b — an n x n / n-vector *sufficient statistic* for
every quadratic-data-term fit (lasso, ridge, elastic net, NNLS, linear
probes). :class:`SufficientStats` makes that object first-class:

  * streaming ``update(block)``     — ingest row blocks without ever
                                      materializing D (one pass, O(k n^2));
  * cross-shard ``merge()``         — shards build local stats, merge is an
                                      n^2 add (the paper's all-reduce);
  * content fingerprinting          — per-block sha256 folded by addition
                                      mod 2^256, so the fingerprint is
                                      independent of ingest order / sharding
                                      but sensitive to multiplicity:
                                      merge(u(a), u(b)) == u(a+b) holds
                                      *exactly*, fingerprint included;
  * checkpoint save/restore         — via repro.checkpoint.manager, so a
                                      serving replica restarts warm;
  * Cholesky rank-k up/downdate     — appending or retiring a k-row block
                                      updates a cached factor in O(n^2 k)
                                      instead of refactorizing in O(n^3).

The pytree registration keeps stats jit/vmap-compatible (the fingerprint and
row count ride as static aux data).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gram as gram_lib
from repro.data.sparse import BlockCSR
# Content fingerprinting lives with the data layer (the block store
# fingerprints at write time); re-exported here for backward compatibility.
from repro.data.store import (   # noqa: F401  (re-export)
    ZERO_FINGERPRINT,
    combine_fingerprints,
    fingerprint_array,
)
from repro.engine import gram_stats

Array = jax.Array


def _content_fingerprint(block_D, block_b) -> str:
    """One definition of content identity for both formats: BlockCSR
    hashes its index/value arrays, dense hashes the matrix — used by
    from_data, update and downdate alike so the ingest and retire paths
    can never disagree. The sparse arrays are CANONICALIZED to 2-D
    (rows, kp) before hashing: fingerprint_array includes the shape, and
    the store hashes per-block (block_m, kp) arrays while a one-block
    BlockCSR view carries (1, block_m, kp) — same bytes, and they must
    hash identically or retiring a store-ingested block would leave a
    non-cancelling fingerprint."""
    if isinstance(block_D, BlockCSR):
        kp = block_D.kp
        return fingerprint_array(
            np.asarray(block_D.indices).reshape(-1, kp),
            np.asarray(block_D.values).reshape(-1, kp), block_b)
    return fingerprint_array(block_D, block_b)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SufficientStats:
    """(G = sum_i D_i^T D_i, c = sum_i D_i^T b_i, row count, fingerprint)."""

    G: Array                      # (n, n) accumulation precision
    c: Array                      # (n,) or (n, r) for stacked right-hand sides
    rows: int = 0
    fingerprint: str = ZERO_FINGERPRINT
    labeled_rows: int = 0         # rows ingested WITH a rhs; c covers these

    # -- pytree protocol: arrays are children, bookkeeping is aux ----------
    def tree_flatten(self):
        return (self.G, self.c), (self.rows, self.fingerprint,
                                  self.labeled_rows)

    @classmethod
    def tree_unflatten(cls, aux, children):
        G, c = children
        rows, fingerprint, labeled_rows = aux
        return cls(G=G, c=c, rows=rows, fingerprint=fingerprint,
                   labeled_rows=labeled_rows)

    # ----------------------------------------------------------------------
    @property
    def n(self) -> int:
        return self.G.shape[0]

    @property
    def fully_labeled(self) -> bool:
        """True iff every ingested row carried a rhs — i.e. c is the rhs
        statistic of the WHOLE dataset and solves may reuse it. A mixed
        ingest (some blocks labeled, some not) leaves c covering only a
        subset of G's rows, which must never be served silently."""
        return self.rows > 0 and self.labeled_rows == self.rows

    @classmethod
    def zero(cls, n: int, rhs: int = 0, dtype=jnp.float32) -> "SufficientStats":
        """Empty accumulator; ``rhs > 0`` tracks stacked right-hand sides."""
        c = jnp.zeros((n, rhs) if rhs else (n,), dtype)
        return cls(G=jnp.zeros((n, n), dtype), c=c)

    @classmethod
    def from_data(cls, D: Array, b: Optional[Array] = None,
                  block_rows: Optional[int] = None,
                  backend: str = "auto") -> "SufficientStats":
        """One streaming pass over (D, b) — the paper's §4 reduction,
        dispatched through the iteration engine (DESIGN.md §8): the fused
        Gram+RHS Pallas kernel on TPU, the chunked lax.scan elsewhere,
        the O(nnz) spgram pass for :class:`BlockCSR` data (fingerprinted
        over its index/value arrays)."""
        m, n = D.shape
        acc = gram_lib._acc_dtype(D.dtype)
        # one fused pass for (m,) and (m, r) rhs alike
        G, c = gram_stats(D, b, backend=backend, block_rows=block_rows)
        if c is None:
            c = jnp.zeros((n,), acc)
        return cls(G=G, c=c, rows=int(m),
                   fingerprint=_content_fingerprint(D, b),
                   labeled_rows=int(m) if b is not None else 0)

    @classmethod
    def from_store(cls, store, dtype=jnp.float32) -> "SufficientStats":
        """Ingest a :class:`repro.data.store.ShardedMatrixStore`: one
        streaming pass over its row blocks, REUSING the store's per-block
        write-time fingerprints instead of re-hashing the data — on a
        multi-terabyte store the hash pass would cost as much as the
        Gram pass itself. The resulting fingerprint equals folding the
        same blocks through :meth:`update` (and ``store.fingerprint``).
        """
        stats = cls.zero(store.n, dtype=dtype)
        for k, (D_b, b_b) in enumerate(store.iter_blocks(padded=False)):
            stats = stats.update(D_b if store.sparse else jnp.asarray(D_b),
                                 jnp.asarray(b_b) if b_b is not None
                                 else None,
                                 block_fingerprint=store.fingerprints[k])
        return stats

    def update(self, block_D: Array, block_b: Optional[Array] = None,
               block_fingerprint: Optional[str] = None) -> "SufficientStats":
        """Fold a (k, n) row block in: G += B^T B, c += B^T b, rows += k.

        Host-driven streaming ingest — the accumulation itself is jitted;
        fingerprinting hashes the concrete block (pass ``block_fingerprint``
        to skip hashing, e.g. when the caller already has a dataset key).
        :class:`BlockCSR` blocks fold through the host spgram pass
        (fingerprinted over their index/value arrays).
        """
        k, n = block_D.shape
        assert n == self.n, f"block width {n} != stats width {self.n}"
        if block_fingerprint is None:
            block_fingerprint = _content_fingerprint(block_D, block_b)
        if isinstance(block_D, BlockCSR):
            G, c = _accumulate_sparse(self.G, self.c, block_D, block_b)
        else:
            G, c = _accumulate(self.G, self.c, block_D, block_b)
        return SufficientStats(
            G=G, c=c, rows=self.rows + int(k),
            fingerprint=combine_fingerprints(self.fingerprint,
                                             block_fingerprint),
            labeled_rows=self.labeled_rows
            + (int(k) if block_b is not None else 0))

    def downdate(self, block_D: Array, block_b: Optional[Array] = None,
                 block_fingerprint: Optional[str] = None) -> "SufficientStats":
        """Retire a previously-ingested block (subtracts its fingerprint)."""
        k, n = block_D.shape
        if block_fingerprint is None:
            block_fingerprint = _content_fingerprint(block_D, block_b)
        if isinstance(block_D, BlockCSR):
            G, c = _accumulate_sparse(self.G, self.c, block_D, block_b,
                                      sign=-1.0)
        else:
            G, c = _accumulate(self.G, self.c, block_D, block_b, sign=-1.0)
        return SufficientStats(
            G=G, c=c, rows=self.rows - int(k),
            fingerprint=combine_fingerprints(self.fingerprint,
                                             block_fingerprint, sign=-1),
            labeled_rows=self.labeled_rows
            - (int(k) if block_b is not None else 0))

    def merge(self, other: "SufficientStats") -> "SufficientStats":
        """Cross-shard reduce: stats of the union of the two row sets."""
        assert self.n == other.n
        return SufficientStats(
            G=self.G + other.G, c=self.c + other.c,
            rows=self.rows + other.rows,
            fingerprint=combine_fingerprints(self.fingerprint,
                                             other.fingerprint),
            labeled_rows=self.labeled_rows + other.labeled_rows)

    # -- wire transfer ------------------------------------------------------
    def to_payload(self) -> dict:
        """Picklable host representation for cross-process shipment (the
        cluster runtime's setup reduction: workers build local stats,
        the coordinator :meth:`merge`-s the payloads — fingerprints
        included, so the merged fingerprint proves every store block was
        folded exactly once)."""
        return {"G": np.asarray(self.G), "c": np.asarray(self.c),
                "rows": int(self.rows), "fingerprint": self.fingerprint,
                "labeled_rows": int(self.labeled_rows)}

    @classmethod
    def from_payload(cls, payload: dict) -> "SufficientStats":
        return cls(G=jnp.asarray(payload["G"]),
                   c=jnp.asarray(payload["c"]),
                   rows=int(payload["rows"]),
                   fingerprint=payload["fingerprint"],
                   labeled_rows=int(payload["labeled_rows"]))

    def factor(self, ridge: float = 0.0) -> Array:
        """Cholesky factor of (G + ridge I) — O(n^3), done once then cached."""
        return gram_lib.gram_factor(self.G, ridge=ridge)

    # -- checkpointing ------------------------------------------------------
    def save(self, manager, step: int, background: bool = False):
        """Persist through repro.checkpoint.manager.CheckpointManager."""
        manager.save(step, {"G": self.G, "c": self.c},
                     extra={"kind": "sufficient_stats", "rows": self.rows,
                            "fingerprint": self.fingerprint,
                            "labeled_rows": self.labeled_rows},
                     background=background)

    @classmethod
    def restore(cls, manager, n: int, rhs: int = 0, step: Optional[int] = None,
                dtype=jnp.float32) -> "SufficientStats":
        like = {"G": jnp.zeros((n, n), dtype),
                "c": jnp.zeros((n, rhs) if rhs else (n,), dtype)}
        tree, extra = manager.restore(like, step=step)
        assert extra.get("kind") == "sufficient_stats", extra
        return cls(G=tree["G"], c=tree["c"], rows=int(extra["rows"]),
                   fingerprint=extra["fingerprint"],
                   labeled_rows=int(extra.get("labeled_rows", 0)))


@jax.jit
def _accumulate(G, c, block_D, block_b, sign=1.0):
    """Fold one block's (B^T B, B^T b) into the running stats — the same
    engine pass the bulk ingest uses, signed for downdates."""
    acc = G.dtype
    Gb, cb = gram_stats(block_D.astype(acc), block_b)
    G = G + sign * Gb
    if cb is not None:
        c = c + sign * cb
    return G, c


def _accumulate_sparse(G, c, block_D, block_b, sign=1.0):
    """Sparse fold — NOT jitted: the O(nnz) gram is a host pass
    (kernels/spgram/ops.py); only the adds run on device."""
    Gb, cb = gram_stats(block_D, block_b)
    G = G + sign * Gb.astype(G.dtype)
    if cb is not None:
        c = c + sign * cb.astype(c.dtype)
    return G, c


# ---------------------------------------------------------------------------
# Cholesky rank-k up/downdate (Golub & Van Loan §12.5 / LINPACK dchud-dchdd)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("sign",))
def _chol_rank1(L: Array, x: Array, sign: float) -> Array:
    """L' with L' L'^T = L L^T + sign * x x^T, in O(n^2).

    Column sweep of Givens (update) / hyperbolic (downdate) rotations; each
    column update is vectorized over rows, the sweep itself is sequential
    (column k feeds column k+1) — hence fori_loop, not scan-over-columns.
    """
    n = L.shape[0]
    idx = jnp.arange(n)

    def body(k, carry):
        L, x = carry
        Lkk = L[k, k]
        xk = x[k]
        r = jnp.sqrt(jnp.maximum(Lkk * Lkk + sign * xk * xk, 1e-30))
        cth = r / Lkk
        sth = xk / Lkk
        col = L[:, k]
        new_col = (col + sign * sth * x) / cth
        new_col = jnp.where(idx > k, new_col, col).at[k].set(r)
        x_new = cth * x - sth * new_col
        x = jnp.where(idx > k, x_new, x)
        return L.at[:, k].set(new_col), x

    L, _ = jax.lax.fori_loop(0, n, body, (L, x))
    return L


@jax.jit
def chol_update(L: Array, block: Array) -> Array:
    """Rank-k Cholesky update: factor of (L L^T + B^T B) for a (k, n) block.

    Appending k rows to the dataset costs O(n^2 k) here vs O(n^3) for a
    fresh factorization — the serving layer's ingest path.
    """
    block = jnp.atleast_2d(block).astype(L.dtype)

    def one(L, row):
        return _chol_rank1(L, row, 1.0), None

    L, _ = jax.lax.scan(one, L, block)
    return L


@jax.jit
def chol_downdate(L: Array, block: Array) -> Array:
    """Rank-k Cholesky downdate: factor of (L L^T - B^T B).

    Retiring rows (data deletion / sliding-window serving). Only valid while
    the downdated matrix stays positive definite — callers retiring rows
    they previously ingested (plus any ridge) satisfy that by construction.
    """
    block = jnp.atleast_2d(block).astype(L.dtype)

    def one(L, row):
        return _chol_rank1(L, row, -1.0), None

    L, _ = jax.lax.scan(one, L, block)
    return L
