"""Batched multi-problem fit serving over cached sufficient statistics.

The serving contract (ROADMAP north star, paper §4 turned into a subsystem):
a dataset is registered ONCE — one streaming pass builds its
:class:`~repro.service.stats.SufficientStats` — and every subsequent fit
request against that dataset fingerprint is answered from cache:

  * quadratic-data-term problems (ridge / lasso / elastic_net / nnls) solve
    straight from (G, c): no Gram pass, no data pass when the request
    reuses the registered b; requests carrying fresh label vectors share
    ONE fused D^T B pass per micro-batch;
  * Cholesky factors are LRU-cached per (fingerprint, ridge); appending or
    retiring data blocks up/downdates both the stats and every live factor
    in O(n^2 k) (repro.service.stats.chol_update) instead of refactorizing;
  * other registered problems (logistic, svm, huber, ...) fall back to the
    full registry solver on the stored data — still one entry point.

Requests queue in a micro-batching window and are coalesced by
(problem, fingerprint, solver parameters) into stacked solves
(repro.service.batching). ``ServerCounters`` makes the cache behaviour
assertable: a warm second fit on the same fingerprint performs zero
additional Gram passes.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import MetricsRegistry, summarize_histogram
from repro.service import batching, registry
from repro.service.stats import SufficientStats, chol_update, chol_downdate

Array = jax.Array

_req_ids = itertools.count()


@dataclasses.dataclass
class FitRequest:
    """One fit against a registered dataset.

    ``b`` overrides the dataset's own right-hand side (a linear probe's
    label vector); None reuses the c ingested at registration time.
    """

    problem: str
    fingerprint: str
    b: Optional[np.ndarray] = None
    mu: Optional[float] = None
    l2: float = 0.0
    C: float = 1.0
    delta: float = 1.0
    iters: int = 1000
    request_id: int = dataclasses.field(
        default_factory=lambda: next(_req_ids))


@dataclasses.dataclass
class FitResponse:
    request_id: int
    problem: str
    fingerprint: str
    x: Optional[np.ndarray]
    iters: int
    batch_size: int            # how many requests shared this solve
    from_cache: bool           # True iff no Gram pass was spent on this
    # terminal status taxonomy (DESIGN.md §15): "ok" | "error" here;
    # the networked front end adds "degraded" / "deadline" / "rejected"
    status: str = "ok"
    error: Optional[str] = None


_LATENCY_HIST = "server.fit_latency_s"


class ServerCounters:
    """Observable cost accounting — the serving layer's acceptance surface.

    Backed by a :class:`~repro.obs.metrics.MetricsRegistry` (DESIGN.md
    §12): the counters are ordinary ``server.*`` registry series (thread-
    safe — the old dataclass ``+=`` fields raced under concurrent
    submits), plus a submit→response latency histogram labelled
    warm/cold. Counter fields stay readable as plain attributes
    (``counters.gram_passes``) and :meth:`snapshot` keeps the flat
    ``{field: int}`` shape, now with latency percentile summaries."""

    _FIELDS = (
        "requests",            # fits submitted
        "responses",           # fit responses returned
        "batches",             # coalesced group solves executed
        "gram_passes",         # full O(m n^2) passes over a dataset
        "rhs_passes",          # fused O(m n k) D^T B micro-batch passes
        "factorizations",      # fresh O(n^3) Cholesky factorizations
        "factor_updates",      # O(n^2 k) rank-k factor up/downdates
        "factor_cache_hits",
        "factor_cache_misses",
        "full_solves",         # non-gram-path fallbacks to registry.solve
        "errors",              # requests answered status="error"
    )

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        # object.__setattr__-free: plain attrs set before any __getattr__
        self.registry = registry or MetricsRegistry()

    def inc(self, field: str, value: int = 1):
        assert field in self._FIELDS, f"unknown server counter {field!r}"
        self.registry.inc(f"server.{field}", value)

    def observe_latency(self, kind: str, seconds: float):
        """submit→response wall time; ``kind`` is warm (served from
        cache) or cold."""
        self.registry.observe(_LATENCY_HIST, seconds, kind=kind)

    def __getattr__(self, name: str) -> int:
        # only called when normal lookup misses: counter-field reads.
        # registry via __dict__ so a half-constructed instance cannot
        # recurse back into __getattr__
        if name in type(self)._FIELDS:
            reg = self.__dict__.get("registry")
            if reg is not None:
                return int(reg.counter_value(f"server.{name}"))
        raise AttributeError(name)

    def snapshot(self) -> Dict[str, object]:
        out: Dict[str, object] = {f: getattr(self, f)
                                  for f in self._FIELDS}
        lat = {}
        for kind in ("warm", "cold"):
            h = self.registry.histogram_snapshot(_LATENCY_HIST, kind=kind)
            if h is not None:
                lat[kind] = summarize_histogram(h, scale=1e3)  # ms
        if lat:
            out["fit_latency_ms"] = lat
        return out


@dataclasses.dataclass
class _Dataset:
    D: Optional[jax.Array]        # (m, n) row-major data; None = stats-only
    stats: SufficientStats        # stats.fully_labeled gates rhs reuse
    b: Optional[jax.Array] = None  # registered rhs rows (full solves reuse it)


class FitServer:
    """Micro-batching fit server with an LRU Cholesky-factor cache.

    ``window``: max queued requests before ``submit`` auto-flushes.
    ``factor_cache_size``: live (fingerprint, ridge) factors; least recently
    used factors are evicted first.

    Thread safety: every mutation of the queue, the dataset registry,
    and the factor LRU happens under one reentrant lock, so concurrent
    ``submit``/``flush``/``ingest_block`` callers (the networked front
    end's handler threads) can never lose a queued request, double-
    answer one, or corrupt the LRU ordering. Group solves run under the
    lock too — the server is a single logical solver; concurrency is
    the front end's job, consistency is this class's.
    """

    def __init__(self, window: int = 16, factor_cache_size: int = 8):
        self.window = int(window)
        self.factor_cache_size = int(factor_cache_size)
        self.counters = ServerCounters()
        self._lock = threading.RLock()
        self._datasets: Dict[str, _Dataset] = {}
        self._factors: "OrderedDict[Tuple[str, float], Array]" = OrderedDict()
        self._queue: List[FitRequest] = []
        self._submit_t: Dict[int, float] = {}   # request_id -> submit time

    # -- dataset lifecycle --------------------------------------------------
    def register_dataset(self, D: Array, b: Optional[Array] = None,
                         keep_data: bool = True) -> str:
        """One streaming pass -> stats; returns the dataset fingerprint.

        ``keep_data=False`` drops the raw rows after the reduction (stats-
        only serving: quadratic problems with registered b keep working;
        fresh-b and non-gram problems will refuse).
        """
        D = jnp.asarray(D)
        node_shape = D.shape[:2] if D.ndim == 3 else None
        if node_shape is not None:           # accept node-stacked layout
            D = D.reshape(-1, D.shape[-1])
        if b is not None:
            b = jnp.asarray(b)
            # a 2-D b is node-stacked labels when it matches D's node
            # layout, else stacked (m, r) right-hand sides (kept 2-D —
            # flattening would interleave columns against D's rows)
            if b.ndim == 2 and b.shape == node_shape:
                b = b.reshape(-1)
            if b.shape[0] != D.shape[0]:
                raise ValueError(
                    f"rhs has {b.shape[0]} rows but data has {D.shape[0]}")
        stats = SufficientStats.from_data(D, b)
        self.counters.inc("gram_passes")
        with self._lock:
            self._datasets[stats.fingerprint] = _Dataset(
                D=D if keep_data else None, stats=stats,
                b=b if keep_data else None)
        return stats.fingerprint

    def register_stats(self, stats: SufficientStats) -> str:
        """Adopt pre-reduced stats (e.g. merged from remote shards or
        checkpoint-restored): rhs reuse is gated by stats.fully_labeled,
        which travels with the stats through merge and checkpointing."""
        with self._lock:
            self._datasets[stats.fingerprint] = _Dataset(D=None, stats=stats)
        return stats.fingerprint

    def _dataset_for_edit(self, fingerprint: str) -> _Dataset:
        ds = self._datasets.get(fingerprint)
        if ds is None:
            raise KeyError(
                f"unknown dataset fingerprint {fingerprint[:12]}...; "
                "register_dataset() first (or the dataset already moved "
                "to a new fingerprint via ingest/retire)")
        return ds

    def ingest_block(self, fingerprint: str, block_D: Array,
                     block_b: Optional[Array] = None) -> str:
        """Append rows to a registered dataset.

        Stats stream-update in O(k n^2); every live factor for the dataset
        rank-k *updates* in O(n^2 k) — no refactorization, and the dataset
        moves to its new content fingerprint.

        Atomic: every derived object (stats, concatenated rows, updated
        factors) is computed BEFORE the registry is touched, so a failing
        block (shape mismatch, bad rhs) leaves the dataset serving under
        its old fingerprint instead of silently dropping it.
        """
        with self._lock:
            ds = self._dataset_for_edit(fingerprint)
            block_D = jnp.asarray(block_D)
            if block_D.ndim != 2 or block_D.shape[1] != ds.stats.n:
                raise ValueError(
                    f"ingest block shape {tuple(block_D.shape)} does not "
                    f"match dataset width {ds.stats.n}")
            new_stats = ds.stats.update(block_D, block_b)
            new_D = (jnp.concatenate([ds.D, block_D], axis=0)
                     if ds.D is not None else None)
            if ds.b is not None and block_b is not None:
                new_b = jnp.concatenate(
                    [ds.b, jnp.asarray(block_b).reshape(-1)])
            else:
                new_b = None      # raw rhs no longer aligns with the rows
            new_factors = self._rekeyed_factors(fingerprint, block_D,
                                                chol_update)
            # -- commit point: nothing below can fail ---------------------
            self._commit_rekey(new_stats.fingerprint, new_factors)
            del self._datasets[fingerprint]
            self._datasets[new_stats.fingerprint] = _Dataset(
                D=new_D, stats=new_stats, b=new_b)
            return new_stats.fingerprint

    def retire_block(self, fingerprint: str, block_D: Array,
                     block_b: Optional[Array] = None) -> str:
        """Remove previously-ingested rows (sliding-window serving).

        Stats downdate; live factors rank-k *downdate*. The raw row cache
        (if any) is dropped — exact row removal is the stats' job.

        Atomic like :meth:`ingest_block`; additionally validates that the
        downdate is well-posed (row count stays nonnegative, downdated
        factors stay finite) before committing, since retiring rows that
        were never ingested would silently poison G.
        """
        with self._lock:
            ds = self._dataset_for_edit(fingerprint)
            block_D = jnp.asarray(block_D)
            if block_D.ndim != 2 or block_D.shape[1] != ds.stats.n:
                raise ValueError(
                    f"retire block shape {tuple(block_D.shape)} does not "
                    f"match dataset width {ds.stats.n}")
            if block_D.shape[0] > ds.stats.rows:
                raise ValueError(
                    f"cannot retire {block_D.shape[0]} rows from a "
                    f"{ds.stats.rows}-row dataset")
            new_stats = ds.stats.downdate(block_D, block_b)
            new_factors = self._rekeyed_factors(fingerprint, block_D,
                                                chol_downdate)
            for (fp, ridge), L in new_factors.items():
                # an indefinite downdate (rows never ingested) yields
                # NaN/Inf in the hyperbolic rotations — detect it here,
                # before the commit, instead of serving garbage factors
                if not bool(jnp.isfinite(L).all()):
                    raise ValueError(
                        "downdate left the cached factor indefinite "
                        f"(fingerprint {fp[:12]}..., ridge {ridge}) — "
                        "the block was not previously ingested")
            # -- commit point ---------------------------------------------
            self._commit_rekey(new_stats.fingerprint, new_factors)
            del self._datasets[fingerprint]
            self._datasets[new_stats.fingerprint] = _Dataset(
                D=None, stats=new_stats)
            return new_stats.fingerprint

    def _rekeyed_factors(self, old_fp: str, block_D: Array, op
                         ) -> "OrderedDict[Tuple[str, float], Array]":
        """Updated factors for every live (old_fp, ridge) key — computed
        eagerly so the caller can validate them before committing."""
        out: "OrderedDict[Tuple[str, float], Array]" = OrderedDict()
        for (fp, ridge), L in self._factors.items():
            if fp == old_fp:
                out[(fp, ridge)] = op(L, block_D)
        return out

    def _commit_rekey(self, new_fp: str, new_factors):
        """Swap pre-validated factors in under the dataset's new
        fingerprint (pure dict surgery — cannot fail)."""
        for (fp, ridge), L in new_factors.items():
            del self._factors[(fp, ridge)]
            self._factors[(new_fp, ridge)] = L
            self.counters.inc("factor_updates")

    def stats_for(self, fingerprint: str) -> SufficientStats:
        with self._lock:
            return self._datasets[fingerprint].stats

    # -- factor cache -------------------------------------------------------
    def _factor(self, fingerprint: str, ridge: float) -> Array:
        with self._lock:
            key = (fingerprint, float(ridge))
            if key in self._factors:
                self._factors.move_to_end(key)
                self.counters.inc("factor_cache_hits")
                return self._factors[key]
            self.counters.inc("factor_cache_misses")
            L = self._datasets[fingerprint].stats.factor(ridge=ridge)
            self.counters.inc("factorizations")
            self._factors[key] = L
            while len(self._factors) > self.factor_cache_size:
                self._factors.popitem(last=False)
            return L

    # -- request path -------------------------------------------------------
    def submit(self, request: FitRequest) -> List[FitResponse]:
        """Queue a request; auto-flush when the window fills."""
        self.counters.inc("requests")
        with self._lock:
            self._submit_t[request.request_id] = time.perf_counter()
            self._queue.append(request)
            if len(self._queue) >= self.window:
                return self.flush()
        return []

    def flush(self) -> List[FitResponse]:
        """Coalesce the queue into per-(problem, dataset, params) batches.

        Failure containment: one bad group (unknown fingerprint, missing
        mu/b, stats-only dataset asked for raw rows) is answered with
        per-request ``status="error"`` responses and the REMAINING groups
        still solve — the queue was already swapped out, so aborting
        mid-flush would silently lose every sibling request's response.
        """
        with self._lock:
            queue, self._queue = self._queue, []
            groups: "OrderedDict[tuple, List[FitRequest]]" = OrderedDict()
            for req in queue:
                # ridge shares one factor per mu, so it groups by mu (None
                # normalizes to the solver default); FASTA-path problems
                # vmap over per-request mus and coalesce freely.
                mu_key = ((req.mu if req.mu is not None else 1.0)
                          if req.problem == "ridge" else None)
                key = (req.problem, req.fingerprint, req.l2, req.iters,
                       mu_key)
                groups.setdefault(key, []).append(req)
            out: List[FitResponse] = []
            for reqs in groups.values():
                try:
                    out.extend(self._solve_group(reqs))
                except Exception as e:          # noqa: BLE001 — isolate
                    self.counters.inc("errors", len(reqs))
                    err = f"{type(e).__name__}: {e}"
                    out.extend(
                        FitResponse(request_id=r.request_id,
                                    problem=r.problem,
                                    fingerprint=r.fingerprint, x=None,
                                    iters=0, batch_size=len(reqs),
                                    from_cache=False, status="error",
                                    error=err)
                        for r in reqs)
            self.counters.inc("responses", len(out))
            now = time.perf_counter()
            for resp in out:
                # warm = answered from cached stats (no Gram pass spent);
                # requests that bypassed submit() (direct flush of a hand-
                # built queue) have no stamp and observe nothing; error
                # responses carry no latency sample (they would pollute
                # the warm/cold split with failure-path timings)
                t0 = self._submit_t.pop(resp.request_id, None)
                if t0 is not None and resp.status == "ok":
                    self.counters.observe_latency(
                        "warm" if resp.from_cache else "cold", now - t0)
            out.sort(key=lambda r: r.request_id)
            return out

    def solve_one(self, request: FitRequest) -> FitResponse:
        """One synchronous solve OUTSIDE the micro-batch queue — the
        network front end's cold/fallback path. Gram-path problems are
        answered under the server lock (they are cached-factor fast);
        full solves only hold the lock for the dataset lookup and run
        the O(iters · m n) solver outside it, so a long cold solve can
        never stall concurrent warm flushes. Raises on failure (the
        caller owns error containment and breaker accounting)."""
        if request.problem in registry.GRAM_SOLVERS:
            with self._lock:
                return self._solve_group([request])[0]
        with self._lock:
            if request.fingerprint not in self._datasets:
                raise KeyError(
                    f"unknown dataset fingerprint "
                    f"{request.fingerprint[:12]}...; register_dataset() "
                    "first")
        return self._solve_full(request)

    def serve(self, requests: Sequence[FitRequest],
              window_s: float = 0.0) -> List[FitResponse]:
        """Drive a request stream through the micro-batching loop.

        ``window_s`` emulates an arrival window: requests accumulate until
        the window closes (or the queue hits ``window``), then flush.
        """
        out: List[FitResponse] = []
        deadline = time.monotonic() + window_s
        for req in requests:
            out.extend(self.submit(req))
            if window_s and time.monotonic() >= deadline:
                out.extend(self.flush())
                deadline = time.monotonic() + window_s
        out.extend(self.flush())
        return out

    # -- group solvers ------------------------------------------------------
    def _solve_group(self, reqs: List[FitRequest]) -> List[FitResponse]:
        problem = reqs[0].problem
        fp = reqs[0].fingerprint
        if fp not in self._datasets:
            raise KeyError(f"unknown dataset fingerprint {fp[:12]}...; "
                           "register_dataset() first")
        # the registry's stats-path solvers define what serves from cache
        if problem in registry.GRAM_SOLVERS:
            return self._solve_gram_group(problem, fp, reqs)
        return [self._solve_full(req) for req in reqs]

    def _group_rhs(self, fp: str, reqs: List[FitRequest]) -> Array:
        """(k, n) right-hand sides: ONE fused D^T B pass for fresh labels."""
        ds = self._datasets[fp]
        fresh = [r for r in reqs if r.b is not None]
        if fresh:
            if ds.D is None:
                raise ValueError(
                    "request carries fresh b but dataset was registered "
                    "stats-only (keep_data=False)")
            B = jnp.stack(
                [jnp.asarray(r.b).reshape(-1) for r in fresh], axis=1)
            C_fresh = batching.rhs_chunked(ds.D, B)          # (n, k_fresh)
            self.counters.inc("rhs_passes")
        cols, j = [], 0
        for r in reqs:
            if r.b is None:
                # fully_labeled: c covers every row in G — a mixed ingest
                # (some blocks unlabeled) must not serve its partial c.
                if not (ds.stats.fully_labeled and ds.stats.c.ndim == 1):
                    raise ValueError(
                        "request reuses the dataset rhs but none was "
                        "registered — pass b on the request or register "
                        "the dataset with b")
                cols.append(ds.stats.c)
            else:
                cols.append(C_fresh[:, j])
                j += 1
        return jnp.stack(cols, axis=0)                       # (k, n)

    def _solve_gram_group(self, problem: str, fp: str,
                          reqs: List[FitRequest]) -> List[FitResponse]:
        self.counters.inc("batches")
        if problem in ("lasso", "elastic_net"):
            missing = [r.request_id for r in reqs if r.mu is None]
            if missing:
                raise ValueError(
                    f"{problem} requests {missing} have no mu — an l1 "
                    "weight is required (mu=0 would silently serve "
                    "unregularized least squares)")
        C = self._group_rhs(fp, reqs)
        k = len(reqs)
        if problem == "ridge":
            mu = reqs[0].mu if reqs[0].mu is not None else 1.0
            L = self._factor(fp, ridge=mu)
            X = batching.batched_gram_solve(L, C)
            iters = np.ones((k,), np.int32)
        else:
            G = self._datasets[fp].stats.G
            mus = jnp.asarray(
                [r.mu if r.mu is not None else 0.0 for r in reqs],
                G.dtype)
            X, iters = batching.batched_quad_prox(
                G, C, mus, kind=problem, l2=reqs[0].l2,
                iters=reqs[0].iters)
            iters = np.asarray(iters)
        X = np.asarray(X)
        return [
            FitResponse(request_id=r.request_id, problem=problem,
                        fingerprint=fp, x=X[i], iters=int(iters[i]),
                        batch_size=k, from_cache=True)
            for i, r in enumerate(reqs)
        ]

    def _solve_full(self, req: FitRequest) -> FitResponse:
        """Non-quadratic data terms need the rows: registry fallback."""
        ds = self._datasets[req.fingerprint]
        if ds.D is None:
            raise ValueError(
                f"problem {req.problem!r} needs raw data but dataset "
                "was registered stats-only")
        b = req.b if req.b is not None else ds.b
        if b is None:
            raise ValueError(
                f"problem {req.problem!r} needs labels/targets: pass b on "
                "the request or register the dataset with b")
        self.counters.inc("full_solves")
        m, n = ds.D.shape
        D = ds.D.reshape(1, m, n)
        aux = jnp.asarray(b).reshape(1, m)
        res = registry.solve(
            req.problem, D, aux, method="transpose", mu=req.mu, C=req.C,
            delta=req.delta, iters=req.iters, record=False)
        return FitResponse(
            request_id=req.request_id, problem=req.problem,
            fingerprint=req.fingerprint, x=np.asarray(res.x),
            iters=int(res.iters), batch_size=1, from_cache=False)
