"""Serving layer: sufficient statistics as the unit of serving.

  stats     — SufficientStats pytree: streaming update / merge / checkpoint
              + Cholesky rank-k up/downdate.
  registry  — @register_problem dispatch (the fit() entry point's backend)
              + stats-path solvers for quadratic data terms.
  batching  — multi-RHS / mu-grid coalescing over one cached factor.
  server    — FitServer: micro-batching request loop, LRU factor cache,
              observable cost counters.
  admission — token-bucket tenant quotas, bounded-queue load shedding,
              cold-solve circuit breaker (DESIGN.md §15).
  frontend  — FitFrontend: threaded TCP front end over the cluster
              framing; multi-tenant, deadline-aware, degrade-not-fail.
"""
from repro.service.stats import (
    SufficientStats,
    chol_downdate,
    chol_update,
    combine_fingerprints,
    fingerprint_array,
)
from repro.service.registry import (
    GRAM_SOLVERS,
    problems,
    register_problem,
    solve,
)
from repro.service.batching import (
    batched_gram_solve,
    batched_quad_prox,
    lasso_mu_path,
    rhs_chunked,
)
from repro.service.server import (
    FitRequest,
    FitResponse,
    FitServer,
    ServerCounters,
)
from repro.service.admission import (
    Admission,
    AdmissionController,
    CircuitBreaker,
    TokenBucket,
)

__all__ = [
    "SufficientStats", "chol_downdate", "chol_update",
    "combine_fingerprints", "fingerprint_array", "GRAM_SOLVERS", "problems",
    "register_problem", "solve", "batched_gram_solve", "batched_quad_prox",
    "lasso_mu_path", "rhs_chunked", "FitRequest", "FitResponse", "FitServer",
    "ServerCounters", "Admission", "AdmissionController", "CircuitBreaker",
    "TokenBucket",
]

# FitFrontend / FitServiceClient import from repro.service.frontend —
# deliberately NOT re-exported here: frontend pulls in the cluster
# transport, and in-process FitServer users should not pay that import.
