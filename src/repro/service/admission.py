"""Admission control for the networked fit service (DESIGN.md §15).

The front end must degrade instead of failing: under overload it says
"no, retry later" *immediately* (bounded queue + per-tenant token
quotas), and when the expensive cold-solve backend starts failing or
blowing its budget it stops feeding it (circuit breaker) and serves
degraded answers from cache instead of letting the queue collapse.

Three small, independently testable pieces:

  * :class:`TokenBucket` — per-tenant request quota: ``rate`` tokens/s
    refill up to ``burst``; an empty bucket yields a retry-after hint
    (when the next token lands) rather than queueing the request.
  * :class:`AdmissionController` — tenant buckets + a bounded global
    queue.  ``admit`` is the ONLY gate between a decoded fit frame and
    the solve queue; everything it turns away is answered
    ``status="rejected"`` with a retry-after hint, never silently
    dropped or left to grow an unbounded backlog.
  * :class:`CircuitBreaker` — classic closed → open → half-open.
    ``failure_threshold`` consecutive cold-solve failures (exceptions
    OR blown budgets) open it; while open every cold request sheds to a
    degraded cached answer at zero backend cost; after ``reset_after_s``
    one probe request is let through and its outcome closes or re-opens
    the breaker.

All three are thread-safe: handler threads admit concurrently, the
solver thread records breaker outcomes.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional

from repro.obs.metrics import MetricsRegistry


@dataclasses.dataclass(frozen=True)
class Admission:
    """Outcome of one admission decision."""
    ok: bool
    reason: str = ""              # "" | "queue_full" | "quota"
    retry_after_s: float = 0.0    # hint shipped on rejected responses


class TokenBucket:
    """Standard token bucket; NOT thread-safe on its own — the
    controller serializes access."""

    __slots__ = ("rate", "burst", "tokens", "t_last")

    def __init__(self, rate: float, burst: float):
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)
        self.t_last = time.monotonic()

    def try_take(self, now: Optional[float] = None) -> Admission:
        now = time.monotonic() if now is None else now
        self.tokens = min(self.burst,
                          self.tokens + (now - self.t_last) * self.rate)
        self.t_last = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return Admission(ok=True)
        retry = (1.0 - self.tokens) / self.rate if self.rate > 0 else 1.0
        return Admission(ok=False, reason="quota",
                         retry_after_s=round(retry, 4))


class AdmissionController:
    """Per-tenant quotas + a bounded global queue.

    ``max_queue`` bounds how many admitted-but-unanswered requests may
    exist at once (the front end passes its live in-flight count);
    ``tenant_rate``/``tenant_burst`` parameterize each tenant's bucket
    (``None`` rate = unmetered tenants, queue bound still applies).
    """

    def __init__(self, max_queue: int = 256,
                 tenant_rate: Optional[float] = None,
                 tenant_burst: Optional[float] = None,
                 registry: Optional[MetricsRegistry] = None,
                 max_labeled_tenants: int = 32):
        self.max_queue = int(max_queue)
        self.tenant_rate = tenant_rate
        self.tenant_burst = (float(tenant_burst) if tenant_burst is not None
                             else (2.0 * tenant_rate if tenant_rate else 0.0))
        self._buckets: Dict[str, TokenBucket] = {}
        self._lock = threading.Lock()
        self.admitted = 0
        self.rejected = 0
        # Optional per-tenant labelled series (admission.admitted /
        # admission.rejected / admission.tokens gauges) for the scrape
        # endpoint. Tenant names come off the wire, so label cardinality
        # is bounded: the first ``max_labeled_tenants`` distinct names
        # get their own label, later ones collapse into "_other" — a
        # hostile client inventing tenants cannot grow the registry.
        self._registry = registry
        self._max_labeled = int(max_labeled_tenants)
        self._labeled: set = set()

    def _label(self, tenant: str) -> str:
        # caller holds the lock
        if tenant in self._labeled:
            return tenant
        if len(self._labeled) < self._max_labeled:
            self._labeled.add(tenant)
            return tenant
        return "_other"

    def admit(self, tenant: str, in_flight: int) -> Admission:
        """One decision: queue bound first (overload protection beats
        fairness), then the tenant's bucket."""
        with self._lock:
            reg = self._registry
            label = self._label(tenant) if reg is not None else tenant
            if reg is not None:
                reg.set_gauge("admission.queue_depth", in_flight)
            if in_flight >= self.max_queue:
                self.rejected += 1
                if reg is not None:
                    reg.inc("admission.rejected", tenant=label,
                            reason="queue_full")
                # the backlog drains at the service rate; a full queue's
                # retry hint is proportional to how deep the caller
                # would have been, floored so clients do not hammer
                return Admission(ok=False, reason="queue_full",
                                 retry_after_s=max(0.05,
                                                   0.002 * in_flight))
            if self.tenant_rate is not None:
                bucket = self._buckets.get(tenant)
                if bucket is None:
                    bucket = self._buckets[tenant] = TokenBucket(
                        self.tenant_rate, self.tenant_burst)
                adm = bucket.try_take()
                if reg is not None:
                    reg.set_gauge("admission.tokens", bucket.tokens,
                                  tenant=label)
                if not adm.ok:
                    self.rejected += 1
                    if reg is not None:
                        reg.inc("admission.rejected", tenant=label,
                                reason="quota")
                    return adm
            self.admitted += 1
            if reg is not None:
                reg.inc("admission.admitted", tenant=label)
            return Admission(ok=True)

    def bucket_levels(self) -> Dict[str, float]:
        """{tenant -> current token level} (cardinality-capped names)."""
        with self._lock:
            return {self._label(t): b.tokens
                    for t, b in self._buckets.items()}

    def snapshot(self) -> dict:
        with self._lock:
            return {"admitted": self.admitted, "rejected": self.rejected,
                    "tenants": len(self._buckets),
                    "max_queue": self.max_queue}


class CircuitBreaker:
    """Closed → open → half-open breaker for the cold-solve backend.

    ``record_failure`` covers both exception outcomes and blown budgets:
    either way the backend is not producing answers inside the service's
    latency contract, and feeding it more work just grows the backlog.
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, failure_threshold: int = 3,
                 reset_after_s: float = 5.0):
        self.failure_threshold = int(failure_threshold)
        self.reset_after_s = float(reset_after_s)
        self._lock = threading.Lock()
        self._failures = 0
        self._state = self.CLOSED
        self._opened_at = 0.0
        self._probing = False
        self.trips = 0            # observable: times the breaker opened

    @property
    def state(self) -> str:
        with self._lock:
            self._maybe_half_open()
            return self._state

    def _maybe_half_open(self):
        if (self._state == self.OPEN
                and time.monotonic() - self._opened_at >= self.reset_after_s):
            self._state = self.HALF_OPEN
            self._probing = False

    def allow(self) -> bool:
        """May a cold solve be dispatched right now? Half-open lets ONE
        probe through; its outcome decides the next state."""
        with self._lock:
            self._maybe_half_open()
            if self._state == self.CLOSED:
                return True
            if self._state == self.HALF_OPEN and not self._probing:
                self._probing = True
                return True
            return False

    def record_success(self):
        with self._lock:
            self._failures = 0
            self._state = self.CLOSED
            self._probing = False

    def record_failure(self):
        with self._lock:
            self._failures += 1
            if (self._state == self.HALF_OPEN
                    or self._failures >= self.failure_threshold):
                if self._state != self.OPEN:
                    self.trips += 1
                self._state = self.OPEN
                self._opened_at = time.monotonic()
                self._probing = False

    def snapshot(self) -> dict:
        with self._lock:
            self._maybe_half_open()
            return {"state": self._state, "failures": self._failures,
                    "trips": self.trips}
