"""Problem registry — one entry point for every solvable problem.

Replaces the if-chain dispatch that used to live in ``repro.core.fit``:
solvers self-register under ``(problem, method)`` with
:func:`register_problem`, and :func:`solve` is the single dispatch point
that ``repro.core.fit.fit`` (and every call site behind it) routes through.

Two solver surfaces per problem:

  * the *data path*  — ``fn(D, aux, **params) -> FitResult`` on node-stacked
    (N, m_i, n) data, exactly the old ``fit()`` semantics;
  * the *stats path* — for problems whose data term is quadratic
    (lasso / ridge / elastic net / NNLS), ``GRAM_SOLVERS[problem](G, c,
    **params)`` solves straight from cached sufficient statistics. This is
    what the serving layer (repro.service.server) batches and caches: a
    warm request never touches the raw data again.

Registered problems (>= 7 through the one entry point):
  lasso, logistic, svm, sparse_logistic   (seed solvers, relocated here)
  ridge, elastic_net, huber, nnls         (new in the serving layer)
  quantile, group_lasso, multinomial      (executor-backed, DESIGN.md §14)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import consensus as cons
from repro.core import fasta as fasta_lib
from repro.core import gram as gram_lib
from repro.core import prox as prox_lib
from repro.core.oracles import default_tau
from repro.core.unwrapped import UnwrappedADMM
from repro.engine import gram_stats

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class RegisteredSolver:
    problem: str
    method: str
    fn: Callable[..., "FitResult"]
    gram_path: bool = False       # solvable from (G, c) sufficient stats


_REGISTRY: Dict[Tuple[str, str], RegisteredSolver] = {}

# problem -> fn(G, c, **params) -> (x, iters, objective_history|None)
GRAM_SOLVERS: Dict[str, Callable] = {}


def register_problem(problem: str, method: str = "transpose",
                     gram_path: bool = False, aliases: Tuple[str, ...] = ()):
    """Decorator registering ``fn(D, aux, **params) -> FitResult``."""

    def deco(fn):
        for meth in (method,) + tuple(aliases):
            _REGISTRY[(problem, meth)] = RegisteredSolver(
                problem=problem, method=meth, fn=fn, gram_path=gram_path)
        return fn

    return deco


def register_gram_solver(problem: str):
    def deco(fn):
        GRAM_SOLVERS[problem] = fn
        return fn

    return deco


def problems() -> Tuple[str, ...]:
    return tuple(sorted({p for p, _ in _REGISTRY}))


def methods(problem: str) -> Tuple[str, ...]:
    return tuple(sorted(m for p, m in _REGISTRY if p == problem))


def get_solver(problem: str, method: str) -> RegisteredSolver:
    try:
        return _REGISTRY[(problem, method)]
    except KeyError:
        raise ValueError(
            f"unsupported (problem={problem}, method={method}); "
            f"registered problems: {problems()}; "
            f"methods for {problem!r}: {methods(problem) or 'none'}"
        ) from None


def solve(problem: str, D: Array, aux: Array, method: str = "transpose",
          **params) -> "FitResult":
    """The single dispatch point behind ``repro.core.fit.fit``."""
    spec = get_solver(problem, method)
    if params.get("tau") is None and problem in (
            "lasso", "logistic", "svm", "sparse_logistic", "huber"):
        N, mi, n = D.shape
        base = {"sparse_logistic": "logistic", "huber": "svm"}.get(
            problem, problem)
        params["tau"] = default_tau(base, N * mi)
    return spec.fn(D, aux, **params)


def _result(x, iters, history, method, problem):
    from repro.core.fit import FitResult
    return FitResult(x, iters, history, method, problem)


# ---------------------------------------------------------------------------
# Stats-path solvers: x from (G, c) alone — the serving layer's hot path.
# ---------------------------------------------------------------------------

@register_gram_solver("ridge")
def ridge_from_stats(G: Array, c: Array, mu: float = 1.0, iters: int = 0,
                     **_):
    """min 0.5||Dx-b||^2 + mu/2||x||^2  ==  (G + mu I)^{-1} c, closed form.

    The ridge term is added explicitly (not via gram_factor's ridge kwarg)
    so ``mu`` may be a traced scalar — batching vmaps over mu lanes.
    """
    n = G.shape[0]
    A = G + jnp.asarray(mu, G.dtype) * jnp.eye(n, dtype=G.dtype)
    L = gram_lib.gram_factor(A)
    return gram_lib.gram_solve(L, c), 1, None


@register_gram_solver("lasso")
def lasso_from_stats(G: Array, c: Array, mu: float, iters: int = 2000,
                     x0: Optional[Array] = None, l2: float = 0.0, **_):
    # l2 is honoured, not swallowed: a lasso request carrying the elastic-
    # net knob gets the elastic-net solution (l2=0 is plain lasso).
    res = fasta_lib.transpose_reduction_lasso(G, c, mu, iters=iters, x0=x0,
                                              l2=l2)
    return res.x, res.iters, res.objective


@register_gram_solver("elastic_net")
def elastic_net_from_stats(G: Array, c: Array, mu: float, l2: float = 0.0,
                           iters: int = 2000, x0: Optional[Array] = None, **_):
    """min mu|x| + l2/2||x||^2 + 0.5 x^T G x - x^T c: lasso's FASTA with
    the l2 term folded into the smooth part; l2=0 recovers lasso."""
    res = fasta_lib.transpose_reduction_lasso(G, c, mu, iters=iters, x0=x0,
                                              l2=l2)
    return res.x, res.iters, res.objective


@register_gram_solver("nnls")
def nnls_from_stats(G: Array, c: Array, iters: int = 2000,
                    x0: Optional[Array] = None, **_):
    """min_{x>=0} 0.5||Dx-b||^2 — projected gradient (FASTA, prox = clip)."""
    n = G.shape[0]
    if x0 is None:
        x0 = jnp.zeros((n,), G.dtype)
    t0 = 1.0 / fasta_lib.power_lmax(G)
    solver = fasta_lib.Fasta(
        gradg=lambda x: G @ x - c,
        g=lambda x: 0.5 * jnp.vdot(x, G @ x) - jnp.vdot(x, c),
        proxJ=lambda z, t: prox_lib.project_nonneg(z),
        J=lambda x: jnp.asarray(0.0, x.dtype),
    )
    res = solver.run(x0, t0, iters)
    return res.x, res.iters, res.objective


# ---------------------------------------------------------------------------
# Data-path solvers (the old core/fit.py if-chain, relocated).
# ---------------------------------------------------------------------------

def _flatten(D: Array):
    N, mi, n = D.shape
    return D.reshape(N * mi, n), N * mi, n


@register_problem("lasso", "transpose", gram_path=True, aliases=("fasta",))
def _lasso_transpose(D, aux, mu=None, iters=500, x0=None, l2: float = 0.0,
                     **_):
    assert mu is not None
    # §4: direct transpose reduction + single-node FASTA.
    Dflat, m, n = _flatten(D)
    G, c = gram_stats(Dflat, aux.reshape(m))
    x, it, hist = lasso_from_stats(G, c, mu, iters=iters, x0=x0, l2=l2)
    return _result(x, int(it), hist, "transpose", "lasso")


@register_problem("lasso", "consensus")
def _lasso_consensus(D, aux, mu=None, tau=None, iters=500, **_):
    assert mu is not None
    r = cons.ConsensusLasso(mu=mu, tau=tau).run(D, aux, iters)
    return _result(r.z, int(r.iters), r.history.objective,
                   "consensus", "lasso")


@register_problem("logistic", "transpose")
def _logistic_transpose(D, aux, tau=None, iters=500, record=True, x0=None,
                        **_):
    r = UnwrappedADMM(loss=prox_lib.make_logistic(), tau=tau).run(
        D, aux, iters, x0=x0, record=record)
    hist = r.history.objective if r.history else None
    return _result(r.x, int(r.iters), hist, "transpose", "logistic")


@register_problem("logistic", "consensus")
def _logistic_consensus(D, aux, tau=None, iters=500, **_):
    r = cons.ConsensusLogistic(tau=tau).run(D, aux, iters)
    return _result(r.z, int(r.iters), r.history.objective,
                   "consensus", "logistic")


@register_problem("sparse_logistic", "transpose")
def _sparse_logistic_transpose(D, aux, mu=None, tau=None, iters=500,
                               record=True, x0=None, **_):
    assert mu is not None
    # §7 stacking [I; D]: identity block rides on a virtual node.
    Dflat, m, n = _flatten(D)
    D_hat = jnp.concatenate([jnp.eye(n, dtype=D.dtype), Dflat], 0)[None]
    sp = prox_lib.StackedProx(
        blocks=(prox_lib.make_l1(mu), prox_lib.make_logistic()),
        sizes=(n, m),
    )
    aux_hat = jnp.concatenate(
        [jnp.zeros((n,), aux.dtype), aux.reshape(m)])[None]
    r = UnwrappedADMM(loss=sp.as_loss("sparse_logistic"), tau=tau).run(
        D_hat, aux_hat, iters, x0=x0, record=record)
    hist = r.history.objective if r.history else None
    return _result(r.x, int(r.iters), hist, "transpose", "sparse_logistic")


@register_problem("sparse_logistic", "consensus")
def _sparse_logistic_consensus(D, aux, mu=None, tau=None, iters=500, **_):
    assert mu is not None
    r = cons.ConsensusLogistic(mu=mu, tau=tau).run(D, aux, iters)
    return _result(r.z, int(r.iters), r.history.objective,
                   "consensus", "sparse_logistic")


@register_problem("svm", "transpose")
def _svm_transpose(D, aux, C=1.0, tau=None, iters=500, record=True, x0=None,
                   **_):
    r = UnwrappedADMM(loss=prox_lib.make_hinge(C), tau=tau, rho=1.0).run(
        D, aux, iters, x0=x0, record=record)
    hist = r.history.objective if r.history else None
    return _result(r.x, int(r.iters), hist, "transpose", "svm")


@register_problem("svm", "consensus")
def _svm_consensus(D, aux, C=1.0, tau=None, iters=500, **_):
    r = cons.ConsensusSVM(C=C, tau=tau).run(D, aux, iters)
    return _result(r.z, int(r.iters), r.history.objective,
                   "consensus", "svm")


@register_problem("ridge", "transpose", gram_path=True, aliases=("fasta",))
def _ridge_transpose(D, aux, mu=None, **_):
    mu = 1.0 if mu is None else mu
    Dflat, m, n = _flatten(D)
    G, c = gram_stats(Dflat, aux.reshape(m))
    x, it, hist = ridge_from_stats(G, c, mu=mu)
    return _result(x, it, hist, "transpose", "ridge")


@register_problem("elastic_net", "transpose", gram_path=True,
                  aliases=("fasta",))
def _elastic_net_transpose(D, aux, mu=None, l2: float = 0.0, iters=500,
                           x0=None, **_):
    assert mu is not None
    Dflat, m, n = _flatten(D)
    G, c = gram_stats(Dflat, aux.reshape(m))
    x, it, hist = elastic_net_from_stats(G, c, mu=mu, l2=l2, iters=iters,
                                         x0=x0)
    return _result(x, int(it), hist, "transpose", "elastic_net")


@register_problem("nnls", "transpose", gram_path=True, aliases=("fasta",))
def _nnls_transpose(D, aux, iters=500, x0=None, **_):
    Dflat, m, n = _flatten(D)
    G, c = gram_stats(Dflat, aux.reshape(m))
    x, it, hist = nnls_from_stats(G, c, iters=iters, x0=x0)
    return _result(x, int(it), hist, "transpose", "nnls")


@register_problem("huber", "transpose")
def _huber_transpose(D, aux, delta: float = 1.0, tau=None, iters=500,
                     record=True, x0=None, **_):
    """Robust regression min sum h_delta(Dx - b): unwrapped ADMM, huber prox."""
    r = UnwrappedADMM(loss=prox_lib.make_huber(delta), tau=tau).run(
        D, aux, iters, x0=x0, record=record)
    hist = r.history.objective if r.history else None
    return _result(r.x, int(r.iters), hist, "transpose", "huber")


@register_problem("quantile", "transpose")
def _quantile_transpose(D, aux, q: float = 0.5, tau=None, iters=500,
                        record=True, x0=None, **_):
    """Quantile regression min sum rho_q(Dx - b): pinball prox, same
    transpose-reduction loop (and the fused Pallas prox kind)."""
    r = UnwrappedADMM(loss=prox_lib.make_quantile(q),
                      tau=1.0 if tau is None else tau).run(
        D, aux, iters, x0=x0, record=record)
    hist = r.history.objective if r.history else None
    return _result(r.x, int(r.iters), hist, "transpose", "quantile")


@register_problem("group_lasso", "transpose")
def _group_lasso_transpose(D, aux, mu=None, groups=None, tau=None,
                           iters=500, record=True, x0=None, **_):
    """Group lasso min 0.5||Dx-b||^2 + mu sum_g ||x_g||: least-squares
    data term plus an x-space group penalty solved by the driver's
    composite prox-gradient x-update (repro.exec.base.Regularizer)."""
    assert mu is not None
    from repro.exec import make_group_lasso_reg
    n = D.shape[-1]
    g = jnp.arange(n) // 4 if groups is None else jnp.asarray(groups)
    reg = make_group_lasso_reg(float(mu), g, int(g[-1]) + 1)
    r = UnwrappedADMM(loss=prox_lib.make_least_squares(),
                      tau=1.0 if tau is None else tau).solve(
        D, aux, max_iters=iters, x0=x0, record=record, reg=reg)
    hist = r.history.objective if r.history else None
    return _result(r.x, int(r.iters), hist, "transpose", "group_lasso")


@register_problem("multinomial", "transpose")
def _multinomial_transpose(D, aux, classes: int = 3, tau=None, iters=500,
                           record=True, x0=None, **_):
    """Multinomial logistic over K classes: (m, K) splitting iterates
    through the same multi-RHS Gram machinery; x comes back (n, K)."""
    r = UnwrappedADMM(loss=prox_lib.make_multinomial(int(classes)),
                      tau=0.5 if tau is None else tau).solve(
        D, aux, max_iters=iters, x0=x0, record=record)
    hist = r.history.objective if r.history else None
    return _result(r.x, int(r.iters), hist, "transpose", "multinomial")
