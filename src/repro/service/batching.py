"""Multi-request coalescing: one cached factor, many solves.

The asymmetry the serving layer exploits: after the O(m n^2) Gram reduction,
every additional solve against the same dataset is O(n^2) — so requests that
share a dataset fingerprint should share one factor and run as a *stacked*
solve. Three coalescing shapes:

  * ``batched_gram_solve``   — k right-hand sides through one Cholesky
                               factor (64 ridge probes = one (n, 64) solve);
  * ``batched_quad_prox``    — vmapped FASTA over stacked (c_j, mu_j) lanes
                               sharing one G (lasso mu-path, elastic-net
                               grids, NNLS probe banks);
  * ``rhs_chunked``          — the fused one-pass D^T B for a whole
                               micro-batch of label vectors (one data pass
                               for k requests, not k passes).

All are jit-compiled with static batch shape; the server buckets requests
so recompilation only happens per (problem, n, k) shape class.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import gram as gram_lib
from repro.service import registry

Array = jax.Array


@jax.jit
def batched_gram_solve(L: Array, rhs_stack: Array) -> Array:
    """Solve (L L^T) X = rhs for k stacked right-hand sides.

    ``rhs_stack`` is (k, n); returns (k, n). One triangular solve pair over
    an (n, k) block — the BLAS-3 path, not k separate BLAS-2 solves.
    """
    return gram_lib.gram_solve(L, rhs_stack.T).T


@partial(jax.jit, static_argnames=("block_rows",))
def rhs_chunked(D: Array, B: Array, block_rows: int = 1024) -> Array:
    """Streaming D^T B over row blocks: (m, n), (m, k) -> (n, k).

    The micro-batch analogue of gram_and_rhs_chunked's rhs pass — k label
    vectors share one pass over the data (and skip the Gram term, which the
    caller already has cached).
    """
    m, n = D.shape
    acc = gram_lib._acc_dtype(D.dtype)
    Dp = gram_lib.blocked_rows(D, block_rows)
    Bp = gram_lib.blocked_rows(B, block_rows)

    def body(C, blk):
        Db, Bb = blk
        return C + Db.astype(acc).T @ Bb.astype(acc), None

    C0 = jnp.zeros((n, B.shape[1]), acc)
    C, _ = jax.lax.scan(body, C0, (Dp, Bp))
    return C


@partial(jax.jit, static_argnames=("kind", "iters"))
def batched_quad_prox(G: Array, c_stack: Array, mu_stack: Array,
                      kind: str = "lasso", l2: float = 0.0,
                      iters: int = 1000) -> Tuple[Array, Array]:
    """vmapped stats-path solve over stacked (c_j, mu_j) lanes sharing G.

    ``kind`` is any problem with a registered gram solver
    (registry.GRAM_SOLVERS — lasso / elastic_net / nnls / ridge / future
    registrations). Returns (X, iters_used) with X of shape (k, n). A lasso
    regularization path is the degenerate case c_stack = tile(c),
    mu_stack = the mu grid.
    """
    try:
        solver = registry.GRAM_SOLVERS[kind]
    except KeyError:
        raise ValueError(
            f"no gram solver registered for {kind!r}; "
            f"available: {sorted(registry.GRAM_SOLVERS)}") from None

    def one(c, mu):
        x, it, _ = solver(G, c, mu=mu, l2=l2, iters=iters)
        return x, jnp.asarray(it)

    return jax.vmap(one)(c_stack, mu_stack)


def lasso_mu_path(G: Array, c: Array, mus: Array,
                  iters: int = 1000) -> Array:
    """Full regularization path from ONE cached Gram: (len(mus), n)."""
    k = mus.shape[0]
    c_stack = jnp.broadcast_to(c, (k,) + c.shape)
    X, _ = batched_quad_prox(G, c_stack, jnp.asarray(mus), kind="lasso",
                             iters=iters)
    return X
